//! Token-level generation: autoregressive decode as a first-class serving
//! workload on top of the pipelined batcher.
//!
//! This is the workload CLoQ's quantize+init stage exists to serve: the
//! paper evaluates its calibrated LoRA initialization by *decoding*
//! (language generation, arithmetic reasoning), and serving stacks are
//! judged on time-to-first-token (TTFT) and inter-token latency (ITL)
//! under continuous batching — not per-layer matvec throughput. Before
//! this module the engine only exposed raw forwards and caller-`StepFn`
//! sessions; generation is now a typed request:
//!
//! ```ignore
//! let route = engine.route(&["blk0", "blk1", "lm_head"])?;
//! let ticket = engine.generate(GenRequest::new(
//!     route, "Q: 17+25=?", GenParams::greedy(32).stop("\n")));
//!
//! // Per-token, non-blocking: each call is a Completion over GenEvent.
//! loop {
//!     match ticket.next_token().wait()? {
//!         GenEvent::Token { piece, .. } => print!("{piece}"),
//!         GenEvent::Done(resp) => { println!(" [{}]", resp.finish.as_str()); break }
//!     }
//! }
//! ```
//!
//! # How a generation rides the batcher
//!
//! [`start`] tokenizes the prompt with the byte-level seed tokenizer
//! (`[BOS] + data::tokenizer::encode`), folds every prompt token into the
//! session's [`SessionState`] (**prefill** — pure CPU, no model calls),
//! and submits ONE engine session ([`SessionRequest`]) whose `StepFn` is
//! the decode loop: after each full-model forward the step samples a
//! token from the logits, streams it to the caller, folds it into the
//! state, and returns the next input — or `None` on a stop condition
//! (EOS, `max_tokens`, stop-string, cancellation). Every forward re-enters
//! the hop machinery, so CONCURRENT generation sessions coalesce into
//! shared grouped-kernel micro-batches at every layer, token by token —
//! continuous batching at token granularity, for free, because the decode
//! loop lives inside the engine rather than round-tripping per token.
//!
//! The logits vector is the final route layer's output, so the effective
//! vocabulary is that layer's `cols`; sampled ids outside the byte
//! tokenizer's range decode to the empty string (the EOS id `2` still
//! terminates when the head is wide enough to emit it).
//!
//! # Determinism and the parity contract
//!
//! Greedy decode through the continuous batcher is **bit-identical (0 ULP
//! per step)** to the caller-driven serial reference [`generate_serial`]:
//! both paths share [`GenCore`] (one code path for sample → stop-check →
//! absorb), the default state's recurrence is exact f64 arithmetic
//! ([`state`]), and each hop's kernel is bit-identical to a serial
//! [`PackedLayer::forward`] whatever batch it rides in (the contract in
//! `serve::packed`). So identical prompts yield identical token
//! sequences, texts, and final logits bits — across adapters, hot-swaps,
//! and any number of concurrent sessions (`rust/tests/parity_generate.rs`).
//! Seeded sampling is reproducible the same way: the RNG stream is
//! per-session ([`Sampler`]), so batching interleave cannot perturb it.
//!
//! # Observability
//!
//! Admission bumps `gen_sessions_total`; every sampled token bumps
//! `gen_tokens_total`; the first sample observes `gen_ttft_seconds` and
//! each subsequent one `gen_itl_seconds` — all in the engine's sharded
//! telemetry with Prometheus rows, benched end-to-end (Poisson arrivals,
//! heavy-tailed lengths) by `benches/bench_generate.rs`.
//!
//! [`PackedLayer::forward`]: crate::serve::packed::PackedLayer::forward

pub mod sampler;
pub mod state;

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::data::tokenizer;
use crate::serve::adapters::{AdapterId, AdapterSet};
use crate::serve::completion::{self, CompleteFn, Completion, CompletionHandle};
use crate::serve::engine::ServeEngine;
use crate::serve::error::ServeError;
use crate::serve::forward::{forward_route_serial, SessionRequest, StepFn};
use crate::serve::packed::{PackedModel, Route};
use crate::serve::telemetry::{Counter, Metric};

pub use sampler::{argmax, Sampler, Sampling};
pub use state::{hash_embed, HashEmbedState, SessionState, EMBED_DECAY};

/// Why a generation ended.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// The sampler emitted the tokenizer's EOS id.
    Eos,
    /// `max_tokens` tokens were sampled.
    MaxTokens,
    /// A stop-string appeared in the generated text (the final text is
    /// truncated at the match; already-streamed pieces are not recalled).
    Stop,
    /// [`GenTicket::cancel`] (or a dropped HTTP client) ended the session
    /// at the next token boundary.
    Cancelled,
}

impl FinishReason {
    /// Stable wire string (the `finish` field of `/v1/generate` replies).
    pub fn as_str(&self) -> &'static str {
        match self {
            FinishReason::Eos => "eos",
            FinishReason::MaxTokens => "max-tokens",
            FinishReason::Stop => "stop",
            FinishReason::Cancelled => "cancelled",
        }
    }
}

/// The sampling/stopping knobs of one generation, separate from the
/// routing so the serial parity reference can share them verbatim.
#[derive(Clone, Debug)]
pub struct GenParams {
    /// Hard cap on sampled tokens (0 = prefill only: one forward, no
    /// tokens, `finish = MaxTokens`).
    pub max_tokens: usize,
    pub sampling: Sampling,
    /// Seed of the session's private RNG stream (ignored by greedy).
    pub seed: u64,
    /// Stop-strings matched against the accumulated generated text.
    pub stop: Vec<String>,
}

impl GenParams {
    /// Greedy decode up to `max_tokens` — the deterministic default.
    pub fn greedy(max_tokens: usize) -> GenParams {
        GenParams { max_tokens, sampling: Sampling::Greedy, seed: 0, stop: Vec::new() }
    }

    pub fn sampling(mut self, sampling: Sampling) -> GenParams {
        self.sampling = sampling;
        self
    }

    pub fn seed(mut self, seed: u64) -> GenParams {
        self.seed = seed;
        self
    }

    /// Add a stop-string (matching ends the session; may span tokens).
    pub fn stop(mut self, s: &str) -> GenParams {
        self.stop.push(s.to_string());
        self
    }
}

/// One generation request: where to decode ([`Route`] + optional adapter),
/// what to decode from (the prompt), and how ([`GenParams`], optionally a
/// custom [`SessionState`]).
pub struct GenRequest {
    pub route: Route,
    pub adapter: Option<AdapterId>,
    pub prompt: String,
    pub params: GenParams,
    /// Custom per-session state; `None` uses the default
    /// [`HashEmbedState`] sized to the route head. A custom state must
    /// produce activations of the head's input width.
    pub state: Option<Box<dyn SessionState>>,
}

impl GenRequest {
    /// Base-weights generation along `route`.
    pub fn new(route: Route, prompt: &str, params: GenParams) -> GenRequest {
        GenRequest { route, adapter: None, prompt: prompt.to_string(), params, state: None }
    }

    /// Generation routed through the interned adapter (pinned to one
    /// version at admission, like every engine session).
    pub fn with_adapter(
        route: Route,
        adapter: AdapterId,
        prompt: &str,
        params: GenParams,
    ) -> GenRequest {
        GenRequest {
            route,
            adapter: Some(adapter),
            prompt: prompt.to_string(),
            params,
            state: None,
        }
    }

    /// Replace the default session state.
    pub fn state(mut self, state: Box<dyn SessionState>) -> GenRequest {
        self.state = Some(state);
        self
    }
}

/// One event on a generation's token stream.
#[derive(Clone, Debug)]
pub enum GenEvent {
    /// The `index`-th sampled token (0-based) and its decoded text piece
    /// (empty for ids outside the byte range — specials, oversized vocab).
    Token { index: usize, token: i32, piece: String },
    /// The session ended; repeated for every subsequent `next_token`.
    Done(GenResponse),
}

/// A finished generation: the decoded text, the raw token ids, why it
/// stopped, latency observations, and the underlying traversal's stats.
#[derive(Clone, Debug)]
pub struct GenResponse {
    /// Generated text (stop-string match truncated away; prompt excluded).
    pub text: String,
    /// Sampled token ids, in order (stop/EOS token included).
    pub tokens: Vec<i32>,
    pub finish: FinishReason,
    /// Prompt tokens absorbed during prefill (`[BOS]` included).
    pub prompt_tokens: usize,
    /// Logits of the LAST forward — the 0-ULP parity anchor against
    /// [`generate_serial`].
    pub y: Vec<f64>,
    /// Admission → first sampled token (0.0 when no token was sampled).
    pub ttft_s: f64,
    /// Full-model forwards executed (prefill included).
    pub forwards: usize,
    /// Layer hops executed (`forwards · route_len`).
    pub hops: usize,
    pub queue_s: f64,
    pub compute_s: f64,
    pub wall_s: f64,
    /// Largest micro-batch any hop rode in — >1 means this generation
    /// actually coalesced with concurrent traffic.
    pub max_batch_seen: usize,
    pub mixed_hops: usize,
    pub trace_id: u64,
}

/// The shared decode core: sample → stop-check → absorb, ONE code path
/// used verbatim by both the engine's in-batcher step function and the
/// serial reference — which is what makes the 0-ULP parity contract a
/// property of the kernels alone rather than of two hand-kept loops.
struct GenCore {
    state: Box<dyn SessionState>,
    sampler: Sampler,
    max_tokens: usize,
    stop: Vec<String>,
    tokens: Vec<i32>,
    /// Raw generated BYTES (specials contribute none). Text is decoded
    /// from here at the end, so multi-byte UTF-8 characters assembled
    /// across tokens come out intact — matching `tokenizer::decode` over
    /// the token ids exactly.
    bytes: Vec<u8>,
    /// Byte offset of the earliest stop-string match (final text truncates
    /// here).
    stop_at: Option<usize>,
    finish: Option<FinishReason>,
}

impl GenCore {
    fn new(state: Box<dyn SessionState>, params: &GenParams) -> GenCore {
        GenCore {
            state,
            sampler: Sampler::new(params.sampling.clone(), params.seed),
            max_tokens: params.max_tokens,
            stop: params.stop.clone(),
            tokens: Vec::new(),
            bytes: Vec::new(),
            stop_at: None,
            finish: None,
        }
    }

    /// Absorb the whole prompt (no model calls) and return the prefill
    /// forward's input.
    fn prefill(&mut self, prompt: &[i32]) -> Vec<f64> {
        for &t in prompt {
            self.state.absorb(t);
        }
        self.state.x()
    }

    /// One decode step on the latest forward's logits: the sampled token,
    /// its text piece, and the next forward's input (`None` ends the
    /// session — `finish` is set). Stop conditions are checked in priority
    /// order EOS > stop-string > max-tokens.
    fn step(&mut self, logits: &[f64]) -> (i32, String, Option<Vec<f64>>) {
        let tok = self.sampler.sample(logits) as i32;
        self.tokens.push(tok);
        let piece = tokenizer::decode_token(tok);
        if tok >= tokenizer::BYTE_OFFSET && tok < tokenizer::VOCAB as i32 {
            self.bytes.push((tok - tokenizer::BYTE_OFFSET) as u8);
        }
        if tok == tokenizer::EOS {
            self.finish = Some(FinishReason::Eos);
            return (tok, piece, None);
        }
        if let Some(at) = self.stop_match() {
            self.stop_at = Some(at);
            self.finish = Some(FinishReason::Stop);
            return (tok, piece, None);
        }
        if self.tokens.len() >= self.max_tokens {
            self.finish = Some(FinishReason::MaxTokens);
            return (tok, piece, None);
        }
        self.state.absorb(tok);
        (tok, piece, Some(self.state.x()))
    }

    /// Earliest stop-string match in the generated bytes (a match can span
    /// token boundaries — the accumulated output is checked, not the
    /// latest piece).
    fn stop_match(&self) -> Option<usize> {
        self.stop
            .iter()
            .filter(|s| !s.is_empty())
            .filter_map(|s| {
                let pat = s.as_bytes();
                self.bytes.windows(pat.len()).position(|w| w == pat)
            })
            .min()
    }

    /// The generated text with any stop-string match truncated away.
    fn final_text(&self) -> String {
        let end = self.stop_at.unwrap_or(self.bytes.len());
        String::from_utf8_lossy(&self.bytes[..end]).into_owned()
    }
}

/// In-flight mutable state shared between the engine-side step function
/// and the completion finalizer (only one of them runs at a time — hops
/// are sequential and the finalizer fires after the last one).
struct Flight {
    core: GenCore,
    ttft_s: f64,
    t_last: Option<Instant>,
}

/// The ordered token-event stream between the decode loop (producer) and
/// any number of [`TokenTicket`]s (consumers). Events buffer until asked
/// for; the terminal event (`Done` or a typed error) replays to every
/// subsequent ticket.
struct TokenStream {
    inner: Mutex<StreamInner>,
}

struct StreamInner {
    queue: VecDeque<GenEvent>,
    waiters: VecDeque<completion::CompletionSender<GenEvent>>,
    done: Option<Result<GenEvent, ServeError>>,
}

impl TokenStream {
    fn new() -> TokenStream {
        TokenStream {
            inner: Mutex::new(StreamInner {
                queue: VecDeque::new(),
                waiters: VecDeque::new(),
                done: None,
            }),
        }
    }

    /// Producer side: append one token event (delivered to the oldest
    /// waiting ticket, else buffered). Sends happen OUTSIDE the lock —
    /// a delivery may run an `on_complete` callback inline, and that
    /// callback may immediately ask for the next token.
    fn push(&self, ev: GenEvent) {
        let waiter = {
            let mut g = self.inner.lock().unwrap();
            match g.waiters.pop_front() {
                Some(tx) => Some(tx),
                None => {
                    g.queue.push_back(ev.clone());
                    None
                }
            }
        };
        if let Some(tx) = waiter {
            let _ = tx.send(Ok(ev));
        }
    }

    /// Producer side: set the terminal event and wake every waiter.
    fn finish(&self, terminal: Result<GenEvent, ServeError>) {
        let waiters: Vec<_> = {
            let mut g = self.inner.lock().unwrap();
            g.done = Some(terminal.clone());
            g.waiters.drain(..).collect()
        };
        for tx in waiters {
            let _ = tx.send(terminal.clone());
        }
    }

    /// Consumer side: a completion cell for the next event — a buffered
    /// token, the (replayed) terminal, or a wait slot.
    fn next(&self) -> CompletionHandle<GenEvent> {
        let (tx, rx) = completion::channel();
        let ready = {
            let mut g = self.inner.lock().unwrap();
            if let Some(ev) = g.queue.pop_front() {
                Some(Ok(ev))
            } else if let Some(d) = g.done.clone() {
                Some(d)
            } else {
                g.waiters.push_back(tx);
                return rx;
            }
        };
        let _ = tx.send(ready.expect("checked above"));
        rx
    }
}

/// Handle to ONE upcoming token event — the per-token [`Completion`] of a
/// generation. Resolves to [`GenEvent::Token`] as the decode loop samples,
/// to [`GenEvent::Done`] once the session ends (repeatedly, for every
/// later ticket), or to the session's typed [`ServeError`].
pub struct TokenTicket {
    cell: CompletionHandle<GenEvent>,
}

impl TokenTicket {
    pub fn wait(self) -> Result<GenEvent, ServeError> {
        self.cell.wait()
    }

    pub fn wait_timeout(self, timeout: std::time::Duration) -> Result<GenEvent, ServeError> {
        self.cell.wait_timeout(timeout)
    }
}

impl Completion for TokenTicket {
    type Output = GenEvent;

    fn try_wait(&mut self) -> Option<Result<GenEvent, ServeError>> {
        self.cell.try_take()
    }

    fn on_complete(self, f: CompleteFn<GenEvent>) {
        self.cell.on_complete(f);
    }

    fn wait(self) -> Result<GenEvent, ServeError> {
        TokenTicket::wait(self)
    }

    fn wait_timeout(self, timeout: std::time::Duration) -> Result<GenEvent, ServeError> {
        TokenTicket::wait_timeout(self, timeout)
    }
}

/// Handle to one in-flight generation. Consume it two ways, freely mixed:
/// per token via [`next_token`](GenTicket::next_token) (each a
/// non-blocking [`Completion`] over [`GenEvent`]), or whole via this
/// ticket's own [`Completion`] impl, which resolves to the final
/// [`GenResponse`] exactly like a [`ModelTicket`] — so the HTTP deferral
/// path works unchanged for non-streaming replies.
///
/// [`ModelTicket`]: crate::serve::forward::ModelTicket
pub struct GenTicket {
    stream: Arc<TokenStream>,
    done: CompletionHandle<GenResponse>,
    cancel: Arc<AtomicBool>,
}

impl GenTicket {
    /// A completion cell for the next token event. Tickets taken after the
    /// session ends resolve immediately with the replayed terminal event.
    pub fn next_token(&self) -> TokenTicket {
        TokenTicket { cell: self.stream.next() }
    }

    /// Ask the decode loop to stop at the next token boundary (the session
    /// then completes normally with [`FinishReason::Cancelled`]). The
    /// already-admitted forward still runs — cancellation is cooperative,
    /// like every engine drain path.
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::Release);
    }

    pub fn wait(self) -> Result<GenResponse, ServeError> {
        self.done.wait()
    }

    pub fn wait_timeout(self, timeout: std::time::Duration) -> Result<GenResponse, ServeError> {
        self.done.wait_timeout(timeout)
    }
}

impl Completion for GenTicket {
    type Output = GenResponse;

    fn try_wait(&mut self) -> Option<Result<GenResponse, ServeError>> {
        self.done.try_take()
    }

    fn on_complete(self, f: CompleteFn<GenResponse>) {
        self.done.on_complete(f);
    }

    fn wait(self) -> Result<GenResponse, ServeError> {
        GenTicket::wait(self)
    }

    fn wait_timeout(self, timeout: std::time::Duration) -> Result<GenResponse, ServeError> {
        GenTicket::wait_timeout(self, timeout)
    }
}

/// The prompt's token ids as the decode loop absorbs them: `[BOS]` + the
/// byte-level encoding (shared by both decode paths and the HTTP layer's
/// accounting).
pub fn prompt_tokens(prompt: &str) -> Vec<i32> {
    let mut toks = vec![tokenizer::BOS];
    toks.extend(tokenizer::encode(prompt));
    toks
}

/// Start one generation session on `engine` (the free-function form of
/// [`ServeEngine::generate`]): tokenize, prefill, submit the decode loop,
/// and hand back the [`GenTicket`] immediately. Admission failures
/// (unknown adapter, overload, shutdown, foreign route) resolve the
/// ticket with the usual typed errors.
pub fn start(engine: &ServeEngine, req: GenRequest) -> GenTicket {
    let t0 = Instant::now();
    let (done_tx, done_rx) = completion::channel();
    let stream = Arc::new(TokenStream::new());
    let cancel = Arc::new(AtomicBool::new(false));
    let ticket =
        GenTicket { stream: Arc::clone(&stream), done: done_rx, cancel: Arc::clone(&cancel) };

    // Resolve the head width for the default state. A route that was not
    // built against this engine's model fails typed here (and would be
    // refused at admission regardless).
    let ids = req.route.as_ids();
    let head_rows = match ids.first().and_then(|&id| engine.model().get(id)) {
        Some(l) => l.rows,
        None => {
            let e = ServeError::BadRoute {
                detail: "generate: route is empty or was not built against this engine's model"
                    .to_string(),
            };
            stream.finish(Err(e.clone()));
            let _ = done_tx.send(Err(e));
            return ticket;
        }
    };

    let prompt = prompt_tokens(&req.prompt);
    let n_prompt = prompt.len();
    let state = req.state.unwrap_or_else(|| Box::new(HashEmbedState::new(head_rows)));
    let mut core = GenCore::new(state, &req.params);
    let x0 = core.prefill(&prompt);
    if x0.len() != head_rows {
        let e = ServeError::StepFailed {
            forward: 0,
            detail: format!(
                "session state produced {} values but the route head takes {head_rows} features",
                x0.len()
            ),
        };
        stream.finish(Err(e.clone()));
        let _ = done_tx.send(Err(e));
        return ticket;
    }

    let tel = engine.telemetry_handle();
    tel.incr(Counter::GenSessions);

    let flight = Arc::new(Mutex::new(Flight { core, ttft_s: 0.0, t_last: None }));

    // The decode loop, run inside the engine after every full forward:
    // sample from the logits, stream the token, fold it into the state,
    // and re-enter — or end the session at a stop condition.
    let step_flight = Arc::clone(&flight);
    let step_stream = Arc::clone(&stream);
    let step_cancel = Arc::clone(&cancel);
    let step_tel = Arc::clone(&tel);
    let step: StepFn = Box::new(move |_k, y| {
        let (event, next) = {
            let mut g = step_flight.lock().unwrap();
            if step_cancel.load(Ordering::Acquire) {
                g.core.finish = Some(FinishReason::Cancelled);
                return None;
            }
            let now = Instant::now();
            let (token, piece, next) = g.core.step(y);
            let index = g.core.tokens.len() - 1;
            if index == 0 {
                g.ttft_s = now.duration_since(t0).as_secs_f64();
                step_tel.observe(Metric::GenTtft, g.ttft_s);
            } else if let Some(prev) = g.t_last {
                step_tel.observe(Metric::GenItl, now.duration_since(prev).as_secs_f64());
            }
            g.t_last = Some(now);
            step_tel.incr(Counter::GenTokens);
            (GenEvent::Token { index, token, piece }, next)
        };
        // Deliver outside the flight lock: a waiting consumer's callback
        // runs inline on this worker.
        step_stream.push(event);
        next
    });

    // steps = max_tokens + 1: the prefill forward produces the logits the
    // first token is sampled from, and the step fn ends the session before
    // a (max_tokens + 1)-th forward can start. max_tokens == 0 runs the
    // prefill forward alone and replies without sampling.
    let steps = req.params.max_tokens + 1;
    let session = match req.adapter {
        Some(a) => SessionRequest::with_adapter(req.route, a, x0, steps, step),
        None => SessionRequest::new(req.route, x0, steps, step),
    };
    let model_ticket = engine.submit_session(session);

    // Finalizer: fold the traversal's outcome and the decode state into
    // the GenResponse, close the token stream, resolve the done cell.
    let fin_stream = stream;
    model_ticket.on_complete(Box::new(move |r| match r {
        Ok(mr) => {
            let resp = {
                let mut g = flight.lock().unwrap();
                let finish = g.core.finish.take().unwrap_or(FinishReason::MaxTokens);
                GenResponse {
                    text: g.core.final_text(),
                    tokens: g.core.tokens.clone(),
                    finish,
                    prompt_tokens: n_prompt,
                    y: mr.y,
                    ttft_s: g.ttft_s,
                    forwards: mr.forwards,
                    hops: mr.hops,
                    queue_s: mr.queue_s,
                    compute_s: mr.compute_s,
                    wall_s: mr.wall_s,
                    max_batch_seen: mr.max_batch_seen,
                    mixed_hops: mr.mixed_hops,
                    trace_id: mr.trace_id,
                }
            };
            fin_stream.finish(Ok(GenEvent::Done(resp.clone())));
            let _ = done_tx.send(Ok(resp));
        }
        Err(e) => {
            fin_stream.finish(Err(e.clone()));
            let _ = done_tx.send(Err(e));
        }
    }));

    ticket
}

/// The caller-driven serial decode the parity suite pins [`start`]
/// against: same tokenization, same [`GenCore`], same default state —
/// but every forward is a direct [`forward_route_serial`] call on the
/// caller's thread. Greedy decode through the batcher must match this
/// reference at 0 ULP (`rust/tests/parity_generate.rs`); it is also the
/// no-engine baseline `benches/bench_generate.rs` compares against.
pub fn generate_serial(
    model: &PackedModel,
    route: &Route,
    adapter: Option<&AdapterSet>,
    prompt: &str,
    params: &GenParams,
) -> GenResponse {
    let t0 = Instant::now();
    let head = model
        .get(route.as_ids()[0])
        .expect("generate_serial: route was built against a different model");
    let toks = prompt_tokens(prompt);
    let n_prompt = toks.len();
    let mut core = GenCore::new(Box::new(HashEmbedState::new(head.rows)), params);
    let x0 = core.prefill(&toks);

    let steps = params.max_tokens + 1;
    let mut ttft_s = 0.0;
    let mut y = forward_route_serial(model, route, adapter, &x0);
    let mut forwards = 1usize;
    while forwards < steps {
        let t_tok = Instant::now();
        let (_tok, _piece, next) = core.step(&y);
        if core.tokens.len() == 1 {
            ttft_s = t_tok.duration_since(t0).as_secs_f64();
        }
        match next {
            None => break,
            Some(x) => {
                y = forward_route_serial(model, route, adapter, &x);
                forwards += 1;
            }
        }
    }

    let finish = core.finish.take().unwrap_or(FinishReason::MaxTokens);
    let hops = forwards * route.len();
    GenResponse {
        text: core.final_text(),
        tokens: core.tokens.clone(),
        finish,
        prompt_tokens: n_prompt,
        y,
        ttft_s,
        forwards,
        hops,
        queue_s: 0.0,
        compute_s: 0.0,
        wall_s: t0.elapsed().as_secs_f64(),
        max_batch_seen: 1,
        mixed_hops: 0,
        trace_id: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::quant::{quantize_rtn, QuantState};
    use crate::serve::packed::PackedLayer;
    use crate::util::prng::Rng;

    fn tiny_model(seed: u64) -> PackedModel {
        let mut rng = Rng::new(seed);
        let mut layers = Vec::new();
        for (name, m, n) in [("emb", 10usize, 6usize), ("head", 6, 12)] {
            let w = Matrix::randn(m, n, 0.4, &mut rng);
            let q = QuantState::Int(quantize_rtn(&w, 4, 8));
            layers.push(PackedLayer::from_state(name, &q).unwrap());
        }
        PackedModel::new(layers)
    }

    fn core_with(params: GenParams) -> GenCore {
        GenCore::new(Box::new(HashEmbedState::new(4)), &params)
    }

    /// Logits whose argmax is `tok` over a width-`n` head.
    fn peaked(n: usize, tok: usize) -> Vec<f64> {
        let mut l = vec![0.0; n];
        l[tok] = 5.0;
        l
    }

    #[test]
    fn core_stops_on_eos_stop_string_and_max_tokens() {
        // EOS: id 2 peaks → finish Eos, empty piece, no absorb.
        let mut c = core_with(GenParams::greedy(10));
        let (tok, piece, next) = c.step(&peaked(12, 2));
        assert_eq!((tok, piece.as_str()), (2, ""));
        assert!(next.is_none());
        assert_eq!(c.finish, Some(FinishReason::Eos));

        // Stop-string spanning two tokens: "h" then "i" with stop "hi".
        let mut c = core_with(GenParams::greedy(10).stop("hi"));
        let (_, _, next) = c.step(&peaked(260, b'h' as usize + 4));
        assert!(next.is_some(), "no match yet");
        let (_, _, next) = c.step(&peaked(260, b'i' as usize + 4));
        assert!(next.is_none(), "\"hi\" completed the stop-string");
        assert_eq!(c.finish, Some(FinishReason::Stop));
        assert_eq!(c.final_text(), "", "match truncated away");
        assert_eq!(c.tokens.len(), 2);

        // Max-tokens: cap 2 ends at the second sample.
        let mut c = core_with(GenParams::greedy(2));
        assert!(c.step(&peaked(260, 70)).2.is_some());
        assert!(c.step(&peaked(260, 71)).2.is_none());
        assert_eq!(c.finish, Some(FinishReason::MaxTokens));
        assert_eq!(c.final_text(), "BC", "ids 70/71 are bytes 'B'/'C'");
    }

    #[test]
    fn stop_string_truncates_mid_text() {
        let mut c = core_with(GenParams::greedy(10).stop("b"));
        for byte in [b'a', b'b'] {
            c.step(&peaked(260, byte as usize + 4));
        }
        assert_eq!(c.finish, Some(FinishReason::Stop));
        assert_eq!(c.final_text(), "a");
        assert_eq!(c.bytes, b"ab", "raw bytes keep the match for the stream");
    }

    #[test]
    fn serial_reference_decodes_deterministically() {
        let m = tiny_model(50);
        let route = m.route(&["emb", "head"]).unwrap();
        let p = GenParams::greedy(5);
        let a = generate_serial(&m, &route, None, "2+2=?", &p);
        let b = generate_serial(&m, &route, None, "2+2=?", &p);
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.text, b.text);
        assert_eq!(a.y, b.y, "bit-identical final logits");
        assert_eq!(a.finish, FinishReason::MaxTokens);
        assert_eq!(a.tokens.len(), 5);
        assert_eq!(a.forwards, 5, "stop at max_tokens skips the extra forward");
        assert_eq!(a.hops, 10);
        assert_eq!(a.prompt_tokens, 1 + "2+2=?".len());
        let c = generate_serial(&m, &route, None, "3+3=?", &p);
        assert_ne!(a.tokens, c.tokens, "different prompts should decode differently");
    }

    #[test]
    fn serial_max_tokens_zero_is_prefill_only() {
        let m = tiny_model(51);
        let route = m.route(&["emb", "head"]).unwrap();
        let r = generate_serial(&m, &route, None, "x", &GenParams::greedy(0));
        assert!(r.tokens.is_empty());
        assert_eq!(r.text, "");
        assert_eq!(r.forwards, 1, "the prefill forward still runs");
        assert_eq!(r.finish, FinishReason::MaxTokens);
        assert_eq!(r.y.len(), 12);
    }

    #[test]
    fn token_stream_orders_buffers_and_replays_the_terminal() {
        let s = TokenStream::new();
        s.push(GenEvent::Token { index: 0, token: 70, piece: "B".into() });
        s.push(GenEvent::Token { index: 1, token: 71, piece: "C".into() });
        // Buffered events come out in order.
        match s.next().wait().unwrap() {
            GenEvent::Token { index, .. } => assert_eq!(index, 0),
            other => panic!("expected token, got {other:?}"),
        }
        match s.next().wait().unwrap() {
            GenEvent::Token { index, .. } => assert_eq!(index, 1),
            other => panic!("buffered token expected, got {other:?}"),
        }
        // A waiter parked while the queue is empty is woken by push.
        let mut parked = s.next();
        assert!(parked.try_take().is_none(), "nothing buffered: the ticket must park");
        s.push(GenEvent::Token { index: 2, token: 72, piece: "D".into() });
        match parked.wait().unwrap() {
            GenEvent::Token { index, .. } => assert_eq!(index, 2),
            other => panic!("push must wake the parked waiter, got {other:?}"),
        }
        let resp = GenResponse {
            text: "BC".into(),
            tokens: vec![70, 71],
            finish: FinishReason::MaxTokens,
            prompt_tokens: 1,
            y: vec![],
            ttft_s: 0.0,
            forwards: 2,
            hops: 2,
            queue_s: 0.0,
            compute_s: 0.0,
            wall_s: 0.0,
            max_batch_seen: 1,
            mixed_hops: 0,
            trace_id: 0,
        };
        s.finish(Ok(GenEvent::Done(resp)));
        for _ in 0..3 {
            match s.next().wait().unwrap() {
                GenEvent::Done(r) => assert_eq!(r.text, "BC"),
                other => panic!("terminal must replay, got {other:?}"),
            }
        }
    }

    #[test]
    fn token_stream_wakes_parked_waiters_on_error() {
        let s = Arc::new(TokenStream::new());
        let w1 = s.next();
        let w2 = s.next();
        s.finish(Err(ServeError::ShuttingDown));
        assert!(matches!(w1.wait(), Err(ServeError::ShuttingDown)));
        assert!(matches!(w2.wait(), Err(ServeError::ShuttingDown)));
    }

    #[test]
    fn prompt_tokens_lead_with_bos() {
        let toks = prompt_tokens("hi");
        assert_eq!(toks, vec![tokenizer::BOS, b'h' as i32 + 4, b'i' as i32 + 4]);
        assert_eq!(prompt_tokens(""), vec![tokenizer::BOS]);
    }
}

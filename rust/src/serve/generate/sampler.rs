//! Deterministic token sampling over a logits vector.
//!
//! Three policies behind one [`Sampler`]:
//!
//! * [`Sampling::Greedy`] — argmax, ties broken toward the LOWEST index.
//!   Consumes no randomness, so greedy decode is a pure function of the
//!   logits — the anchor of the 0-ULP parity contract in
//!   `rust/tests/parity_generate.rs`.
//! * [`Sampling::Temperature`] — softmax at temperature `t`, one draw from
//!   the session's seeded RNG stream.
//! * [`Sampling::TopK`] — the distribution truncated to the `k` largest
//!   logits (ties toward lower indices), renormalized at temperature `t`.
//!
//! Every non-greedy sample consumes EXACTLY one `f64` from the session's
//! own [`Rng`] stream — never from a shared or thread-local source — so a
//! fixed `(seed, logits sequence)` reproduces the same tokens no matter
//! how the batcher interleaves concurrent sessions.

use crate::util::prng::Rng;

/// The sampling policy for one generation session.
#[derive(Clone, Debug, PartialEq)]
pub enum Sampling {
    /// Argmax (lowest index wins ties). Deterministic; ignores the seed.
    Greedy,
    /// Softmax at temperature `t` (`t <= 0` degenerates to greedy).
    Temperature { t: f64 },
    /// Top-`k` truncation, then softmax at temperature `t` over the
    /// survivors (`k == 0` or `k >=` vocab means no truncation; `t <= 0`
    /// degenerates to greedy).
    TopK { k: usize, t: f64 },
}

/// Argmax with the lowest index winning ties (and NaN logits never
/// winning), so the result is well-defined for any input.
pub fn argmax(logits: &[f64]) -> usize {
    assert!(!logits.is_empty(), "argmax over empty logits");
    let mut best = 0usize;
    for (i, &l) in logits.iter().enumerate().skip(1) {
        if l > logits[best] || logits[best].is_nan() {
            best = i;
        }
    }
    best
}

/// A per-session sampler: the policy plus the session's private RNG
/// stream. One instance per generation session; the engine never shares
/// it across sessions (module docs — that is what makes seeded sampling
/// reproducible under concurrency).
pub struct Sampler {
    sampling: Sampling,
    rng: Rng,
}

impl Sampler {
    pub fn new(sampling: Sampling, seed: u64) -> Sampler {
        Sampler { sampling, rng: Rng::new(seed) }
    }

    /// Draw the next token id from `logits` (one id in `0..logits.len()`).
    pub fn sample(&mut self, logits: &[f64]) -> usize {
        match self.sampling {
            Sampling::Greedy => argmax(logits),
            Sampling::Temperature { t } => {
                if t <= 0.0 {
                    return argmax(logits);
                }
                let all: Vec<usize> = (0..logits.len()).collect();
                self.draw(logits, &all, t)
            }
            Sampling::TopK { k, t } => {
                if t <= 0.0 {
                    return argmax(logits);
                }
                if k == 0 || k >= logits.len() {
                    let all: Vec<usize> = (0..logits.len()).collect();
                    return self.draw(logits, &all, t);
                }
                // Largest k logits; ties toward lower indices (sort is by
                // descending logit with ascending index as tie-break, so
                // the cut is deterministic).
                let mut idx: Vec<usize> = (0..logits.len()).collect();
                idx.sort_by(|&a, &b| {
                    logits[b]
                        .partial_cmp(&logits[a])
                        .unwrap_or(std::cmp::Ordering::Equal)
                        .then(a.cmp(&b))
                });
                idx.truncate(k);
                idx.sort_unstable(); // stable cumulative-walk order
                self.draw(logits, &idx, t)
            }
        }
    }

    /// One softmax draw over `cand` at temperature `t`, consuming exactly
    /// one `f64` from the session stream. Max-subtraction keeps every
    /// weight in `(0, 1]`, so the total is finite and at least 1.
    fn draw(&mut self, logits: &[f64], cand: &[usize], t: f64) -> usize {
        let m = cand.iter().map(|&i| logits[i]).fold(f64::NEG_INFINITY, f64::max);
        let weights: Vec<f64> = cand.iter().map(|&i| ((logits[i] - m) / t).exp()).collect();
        let total: f64 = weights.iter().sum();
        let r = self.rng.f64() * total;
        let mut acc = 0.0;
        for (w, &i) in weights.iter().zip(cand) {
            acc += w;
            if r < acc {
                return i;
            }
        }
        *cand.last().expect("sample over empty candidate set")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_is_argmax_with_low_index_ties() {
        let mut s = Sampler::new(Sampling::Greedy, 7);
        assert_eq!(s.sample(&[0.1, 3.0, -1.0, 3.0]), 1, "first max wins the tie");
        assert_eq!(s.sample(&[5.0]), 0);
        assert_eq!(argmax(&[f64::NAN, 1.0, 2.0]), 2, "NaN never wins");
    }

    #[test]
    fn seeded_streams_reproduce_and_differ() {
        let logits = vec![0.0, 1.0, 2.0, 1.5, -3.0];
        let draw_n = |seed: u64| -> Vec<usize> {
            let mut s = Sampler::new(Sampling::Temperature { t: 1.0 }, seed);
            (0..32).map(|_| s.sample(&logits)).collect()
        };
        assert_eq!(draw_n(11), draw_n(11), "same seed, same token stream");
        assert_ne!(draw_n(11), draw_n(12), "different seeds must diverge");
    }

    #[test]
    fn top_k_only_emits_the_k_best() {
        let logits = vec![0.0, 9.0, 1.0, 8.0, 2.0];
        let mut s = Sampler::new(Sampling::TopK { k: 2, t: 1.0 }, 3);
        for _ in 0..64 {
            let tok = s.sample(&logits);
            assert!(tok == 1 || tok == 3, "top-2 of these logits is {{1, 3}}, got {tok}");
        }
    }

    #[test]
    fn degenerate_knobs_fall_back_to_greedy() {
        let logits = vec![0.5, 2.0, 1.0];
        let mut s = Sampler::new(Sampling::Temperature { t: 0.0 }, 1);
        assert_eq!(s.sample(&logits), 1);
        let mut s = Sampler::new(Sampling::TopK { k: 0, t: -1.0 }, 1);
        assert_eq!(s.sample(&logits), 1);
    }

    #[test]
    fn low_temperature_concentrates_on_the_mode() {
        let logits = vec![0.0, 4.0, 0.5];
        let mut s = Sampler::new(Sampling::Temperature { t: 0.05 }, 99);
        let hits = (0..64).filter(|_| s.sample(&logits) == 1).count();
        assert!(hits >= 60, "t=0.05 should almost always pick the mode, got {hits}/64");
    }
}

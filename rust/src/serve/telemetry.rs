//! Engine telemetry: sharded counters, log-scale latency histograms,
//! per-layer / per-adapter attribution, request tracing, and Prometheus
//! text exposition.
//!
//! # Design: shard on write, merge on read
//!
//! The engine's hot path (admission → micro-batch → reply) must never
//! take a stats mutex: a single `Mutex<EngineStats>` serializes every
//! batch completion of every worker behind one cache line. Instead a
//! [`Telemetry`] handle owns a small power-of-two array of **shards**,
//! each a cache-line-aligned block of relaxed atomic counters plus one
//! fixed-bucket histogram per [`Metric`]. Every thread picks a shard
//! once (round-robin at first use, stored in a thread-local) and then
//! only ever touches its own shard's atomics — workers on different
//! shards never contend, and nothing on the hot path allocates, hashes,
//! or locks. [`Telemetry::snapshot`] merges the shards into one
//! [`TelemetrySnapshot`]; the merge cost is paid by the scraper, not the
//! request.
//!
//! # Histograms: log-linear buckets, bounded error
//!
//! Latencies are recorded in nanoseconds into a fixed log-linear layout:
//! 4 sub-buckets per power-of-two octave (2 mantissa bits) from 256 ns
//! to ~68.7 s, plus an underflow and an overflow bucket —
//! [`HIST_BUCKETS`] buckets total, so a histogram is one flat array of
//! atomics and `observe` is two adds (bucket + nanosecond sum). The
//! bucket holding a value is never more than 1/4 octave wide, so any
//! quantile estimate ([`HistSnapshot::quantile`]) is within 25% of the
//! true value — tight enough for p50/p95/p99 dashboards at zero
//! allocation.
//!
//! # Attribution without hashing
//!
//! Per-layer and per-adapter breakdowns are plain arrays of atomic
//! slots indexed by the interned [`LayerId`](crate::serve::packed::LayerId)
//! index / [`AdapterId`](crate::serve::adapters::AdapterId) slot — the
//! same integers admission already holds, consistent with the typed
//! façade's no-hashing contract. Adapter slots beyond
//! [`TelemetryOptions::max_tracked_adapters`] aggregate into one
//! overflow slot instead of growing.
//!
//! # Tracing
//!
//! When enabled, every admitted request gets a process-unique trace id
//! and a [`TraceBuf`] that rides its `Pending` hop through the engine,
//! collecting timestamped span events (admitted → enqueued → hop N with
//! batch/queue/kernel detail → replied). Finished traces land in a
//! bounded ring; requests slower than
//! [`TelemetryOptions::slow_threshold_s`] are *also* kept in a separate
//! slow ring and logged at `Warn` through `util::logging`, so one slow
//! request leaves an inspectable span timeline behind without any
//! sampling infrastructure.
//!
//! The per-request cost of all of this is bounded by the
//! `bench_telemetry` gate: instrumented coalescing throughput must stay
//! within 5% of a telemetry-disabled engine
//! ([`TelemetryOptions::disabled`]), enforced against
//! `BENCH_telemetry.json` by `scripts/bench_diff.py`.
//!
//! `EngineStats` remains the back-compat counter view — it is now
//! *derived* from a snapshot ([`TelemetrySnapshot::engine_stats`]), not
//! tracked separately.

use std::cell::Cell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::serve::engine::EngineStats;

// ---- counters ----

/// Monotonic event counters, one per observable engine/durability event.
/// Indexed contiguously so a shard stores them as one flat atomic array;
/// [`Counter::ALL`] drives the snapshot merge and the Prometheus render.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Counter {
    /// Single-layer requests served successfully.
    SinglesOk,
    /// Model/session requests answered successfully.
    ModelsOk,
    /// Full-model forward passes completed by traversals.
    SessionForwards,
    /// Riders served across all successful micro-batches.
    Hops,
    /// Successful micro-batches executed.
    Batches,
    /// Micro-batches that mixed more than one adapter group.
    MixedBatches,
    /// Requests refused at admission.
    Rejected,
    /// Micro-batches whose kernel panicked.
    BatchPanics,
    /// Single-layer riders resolved with an error.
    SinglesFailed,
    /// Model/session requests resolved with an error.
    ModelsFailed,
    /// Adapter-WAL records appended.
    WalAppends,
    /// Adapter-WAL fsync batches issued.
    WalFsyncs,
    /// Adapter-WAL compactions (including torn-tail repairs).
    WalCompactions,
    /// Adapter-WAL events replayed at boot.
    WalReplayEvents,
    /// Mapped code sections CRC-verified on first kernel touch.
    CrcLazyVerifications,
    /// Code sections whose lazy CRC verification failed.
    CrcFailures,
    /// Artifact opens through the eager (fully-copied) path.
    ArtifactOpensEager,
    /// Artifact opens through the zero-copy mmap path.
    ArtifactOpensMapped,
    /// Requests whose wall time exceeded the slow-trace threshold.
    SlowRequests,
    /// Finished traces evicted from the bounded recent ring.
    TracesDropped,
    /// Micro-batch groups an idle worker took from another worker's shard
    /// (sharded dispatch only).
    Steals,
    /// Traversal hops pushed directly into their next layer's shard by a
    /// finishing batch (sharded dispatch only).
    ShardReentries,
    /// TCP connections accepted by the HTTP front-end (including ones
    /// shed with a 503 at the connection cap).
    HttpConnections,
    /// HTTP responses with a 2xx status.
    HttpOk,
    /// HTTP responses with a 4xx status (including auth/quota rejects).
    HttpClientErrors,
    /// HTTP responses with a 5xx status.
    HttpServerErrors,
    /// Requests refused with 401 (missing or unknown bearer token).
    HttpAuthRejects,
    /// Requests refused with 429 by a tenant's in-flight quota, BEFORE
    /// engine admission (engine-side `Overloaded` counts in `Rejected`).
    HttpQuotaRejects,
    /// Generation sessions admitted (`ServeEngine::generate`).
    GenSessions,
    /// Tokens sampled by generation decode loops.
    GenTokens,
    /// Adapter-WAL compaction snapshots written (`CLOQSNP1`).
    WalSnapshots,
}

pub const N_COUNTERS: usize = 31;

impl Counter {
    pub const ALL: [Counter; N_COUNTERS] = [
        Counter::SinglesOk,
        Counter::ModelsOk,
        Counter::SessionForwards,
        Counter::Hops,
        Counter::Batches,
        Counter::MixedBatches,
        Counter::Rejected,
        Counter::BatchPanics,
        Counter::SinglesFailed,
        Counter::ModelsFailed,
        Counter::WalAppends,
        Counter::WalFsyncs,
        Counter::WalCompactions,
        Counter::WalReplayEvents,
        Counter::CrcLazyVerifications,
        Counter::CrcFailures,
        Counter::ArtifactOpensEager,
        Counter::ArtifactOpensMapped,
        Counter::SlowRequests,
        Counter::TracesDropped,
        Counter::Steals,
        Counter::ShardReentries,
        Counter::HttpConnections,
        Counter::HttpOk,
        Counter::HttpClientErrors,
        Counter::HttpServerErrors,
        Counter::HttpAuthRejects,
        Counter::HttpQuotaRejects,
        Counter::GenSessions,
        Counter::GenTokens,
        Counter::WalSnapshots,
    ];

    /// Prometheus metric name (the `cloq_` prefix is added at render).
    pub fn name(self) -> &'static str {
        match self {
            Counter::SinglesOk => "requests_total",
            Counter::ModelsOk => "model_requests_total",
            Counter::SessionForwards => "session_forwards_total",
            Counter::Hops => "hops_total",
            Counter::Batches => "batches_total",
            Counter::MixedBatches => "mixed_batches_total",
            Counter::Rejected => "rejected_total",
            Counter::BatchPanics => "batch_panics_total",
            Counter::SinglesFailed => "failed_requests_total",
            Counter::ModelsFailed => "failed_model_requests_total",
            Counter::WalAppends => "wal_appends_total",
            Counter::WalFsyncs => "wal_fsyncs_total",
            Counter::WalCompactions => "wal_compactions_total",
            Counter::WalReplayEvents => "wal_replay_events_total",
            Counter::CrcLazyVerifications => "crc_lazy_verifications_total",
            Counter::CrcFailures => "crc_failures_total",
            Counter::ArtifactOpensEager => "artifact_opens_eager_total",
            Counter::ArtifactOpensMapped => "artifact_opens_mapped_total",
            Counter::SlowRequests => "slow_requests_total",
            Counter::TracesDropped => "traces_dropped_total",
            Counter::Steals => "dispatch_steals_total",
            Counter::ShardReentries => "shard_reentries_total",
            Counter::HttpConnections => "http_connections_total",
            Counter::HttpOk => "http_requests_2xx_total",
            Counter::HttpClientErrors => "http_requests_4xx_total",
            Counter::HttpServerErrors => "http_requests_5xx_total",
            Counter::HttpAuthRejects => "http_auth_rejects_total",
            Counter::HttpQuotaRejects => "http_quota_rejects_total",
            Counter::GenSessions => "gen_sessions_total",
            Counter::GenTokens => "gen_tokens_total",
            Counter::WalSnapshots => "wal_snapshots_total",
        }
    }

    pub fn help(self) -> &'static str {
        match self {
            Counter::SinglesOk => "Single-layer requests served successfully.",
            Counter::ModelsOk => "Model/session requests answered successfully.",
            Counter::SessionForwards => "Full-model forward passes completed by traversals.",
            Counter::Hops => {
                "Riders served across all successful micro-batches (single-layer requests and \
                 traversal hops)."
            }
            Counter::Batches => "Successful micro-batches executed.",
            Counter::MixedBatches => "Micro-batches that mixed more than one adapter group.",
            Counter::Rejected => "Requests refused at admission.",
            Counter::BatchPanics => "Micro-batches whose kernel panicked.",
            Counter::SinglesFailed => "Single-layer riders resolved with an error.",
            Counter::ModelsFailed => "Model/session requests resolved with an error.",
            Counter::WalAppends => "Adapter-WAL records appended.",
            Counter::WalFsyncs => "Adapter-WAL fsync batches issued.",
            Counter::WalCompactions => {
                "Adapter-WAL compactions (including torn-tail repairs)."
            }
            Counter::WalReplayEvents => "Adapter-WAL events replayed at boot.",
            Counter::CrcLazyVerifications => {
                "Mapped code sections CRC-verified on first kernel touch."
            }
            Counter::CrcFailures => "Code sections whose lazy CRC verification failed.",
            Counter::ArtifactOpensEager => {
                "Artifact opens through the eager (fully-copied, fully-checked) path."
            }
            Counter::ArtifactOpensMapped => {
                "Artifact opens through the zero-copy mmap path."
            }
            Counter::SlowRequests => {
                "Requests whose wall time exceeded the slow-trace threshold."
            }
            Counter::TracesDropped => {
                "Finished traces evicted from the bounded recent ring."
            }
            Counter::Steals => {
                "Micro-batch groups an idle worker took from another worker's shard \
                 (sharded dispatch)."
            }
            Counter::ShardReentries => {
                "Traversal hops pushed directly into their next layer's shard by a \
                 finishing batch (sharded dispatch)."
            }
            Counter::HttpConnections => {
                "TCP connections accepted by the HTTP front-end (including ones shed \
                 with a 503 at the connection cap)."
            }
            Counter::HttpOk => "HTTP responses with a 2xx status.",
            Counter::HttpClientErrors => {
                "HTTP responses with a 4xx status (including auth/quota rejects)."
            }
            Counter::HttpServerErrors => "HTTP responses with a 5xx status.",
            Counter::HttpAuthRejects => {
                "HTTP requests refused with 401 (missing or unknown bearer token)."
            }
            Counter::HttpQuotaRejects => {
                "HTTP requests refused with 429 by a tenant's in-flight quota before \
                 engine admission."
            }
            Counter::GenSessions => "Generation sessions admitted.",
            Counter::GenTokens => "Tokens sampled by generation decode loops.",
            Counter::WalSnapshots => "Adapter-WAL compaction snapshots written.",
        }
    }
}

// ---- histogram metrics ----

/// The latency distributions the engine records, one fixed-bucket
/// histogram per variant per shard.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Metric {
    /// Per-hop wait from admission or re-entry to micro-batch formation.
    HopQueue,
    /// Grouped-kernel time per micro-batch.
    BatchCompute,
    /// Per-hop queue wait plus the kernel time of the batch it rode.
    HopLatency,
    /// End-to-end request latency, admission to reply.
    RequestWall,
    /// Adapter-WAL fsync duration.
    WalFsync,
    /// Artifact store open duration (eager and mapped).
    ArtifactOpen,
    /// Generation time-to-first-token: admission to the first sample.
    GenTtft,
    /// Generation inter-token latency between consecutive samples.
    GenItl,
}

pub const N_METRICS: usize = 8;

impl Metric {
    pub const ALL: [Metric; N_METRICS] = [
        Metric::HopQueue,
        Metric::BatchCompute,
        Metric::HopLatency,
        Metric::RequestWall,
        Metric::WalFsync,
        Metric::ArtifactOpen,
        Metric::GenTtft,
        Metric::GenItl,
    ];

    /// Prometheus metric name (the `cloq_` prefix is added at render).
    pub fn name(self) -> &'static str {
        match self {
            Metric::HopQueue => "hop_queue_seconds",
            Metric::BatchCompute => "batch_compute_seconds",
            Metric::HopLatency => "hop_latency_seconds",
            Metric::RequestWall => "request_wall_seconds",
            Metric::WalFsync => "wal_fsync_seconds",
            Metric::ArtifactOpen => "artifact_open_seconds",
            Metric::GenTtft => "gen_ttft_seconds",
            Metric::GenItl => "gen_itl_seconds",
        }
    }

    pub fn help(self) -> &'static str {
        match self {
            Metric::HopQueue => {
                "Per-hop wait from admission or re-entry to micro-batch formation."
            }
            Metric::BatchCompute => "Grouped-kernel time per micro-batch.",
            Metric::HopLatency => {
                "Per-hop queue wait plus the kernel time of the batch it rode."
            }
            Metric::RequestWall => "End-to-end request latency, admission to reply.",
            Metric::WalFsync => "Adapter-WAL fsync duration.",
            Metric::ArtifactOpen => "Artifact store open duration (eager and mapped).",
            Metric::GenTtft => {
                "Generation time-to-first-token (admission to the first sample)."
            }
            Metric::GenItl => "Generation inter-token latency between consecutive samples.",
        }
    }
}

// ---- histogram bucket layout ----

/// Mantissa bits per octave: 2 → 4 sub-buckets per power of two.
const HIST_SUB_BITS: u32 = 2;
const HIST_SUB: usize = 1 << HIST_SUB_BITS;
/// Values below 2^8 ns (256 ns) share the underflow bucket.
const HIST_MIN_EXP: u32 = 8;
/// Values at or above 2^36 ns (~68.7 s) share the overflow bucket.
const HIST_MAX_EXP: u32 = 36;
/// Underflow + 4 sub-buckets × 28 octaves + overflow.
pub const HIST_BUCKETS: usize =
    ((HIST_MAX_EXP - HIST_MIN_EXP) as usize) * HIST_SUB + 2;

/// The bucket a nanosecond value lands in.
fn bucket_of(ns: u64) -> usize {
    if ns < (1u64 << HIST_MIN_EXP) {
        return 0;
    }
    if ns >= (1u64 << HIST_MAX_EXP) {
        return HIST_BUCKETS - 1;
    }
    let exp = 63 - ns.leading_zeros();
    let sub = ((ns >> (exp - HIST_SUB_BITS)) & (HIST_SUB as u64 - 1)) as usize;
    1 + (exp - HIST_MIN_EXP) as usize * HIST_SUB + sub
}

/// Exclusive upper bound of bucket `i` in nanoseconds (`u64::MAX`
/// sentinel for the overflow bucket).
fn bucket_upper_ns(i: usize) -> u64 {
    if i == 0 {
        return 1u64 << HIST_MIN_EXP;
    }
    if i >= HIST_BUCKETS - 1 {
        return u64::MAX;
    }
    let exp = HIST_MIN_EXP + ((i - 1) / HIST_SUB) as u32;
    let sub = ((i - 1) % HIST_SUB) as u64;
    (1u64 << exp) + ((sub + 1) << (exp - HIST_SUB_BITS))
}

/// One shard-local histogram: bucket counts plus a nanosecond sum (the
/// sum makes `_sum`/means exact even though buckets are approximate).
struct Hist {
    buckets: Vec<AtomicU64>,
    sum_ns: AtomicU64,
}

impl Hist {
    fn new() -> Hist {
        Hist {
            buckets: (0..HIST_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            sum_ns: AtomicU64::new(0),
        }
    }

    fn observe_ns(&self, ns: u64) {
        self.buckets[bucket_of(ns)].fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
    }
}

// ---- shards ----

/// One thread-affine block of atomics. Cache-line aligned so two shards
/// never false-share; a thread writes only its own shard (round-robin
/// assignment at first use), so the hot path is contention-free with
/// enough shards.
#[repr(align(64))]
struct Shard {
    counters: [AtomicU64; N_COUNTERS],
    hists: [Hist; N_METRICS],
    max_batch: AtomicU64,
    /// High-water mark of any dispatch-shard queue depth observed at push
    /// time (sharded dispatch; 0 under the global batcher).
    max_shard_depth: AtomicU64,
}

impl Shard {
    fn new() -> Shard {
        Shard {
            counters: std::array::from_fn(|_| AtomicU64::new(0)),
            hists: std::array::from_fn(|_| Hist::new()),
            max_batch: AtomicU64::new(0),
            max_shard_depth: AtomicU64::new(0),
        }
    }
}

/// Process-wide round-robin source for thread → shard assignment.
static NEXT_SHARD: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// This thread's shard pick (usize::MAX = unassigned). Shared across
    /// all Telemetry instances — the pick is masked per-instance.
    static MY_SHARD: Cell<usize> = const { Cell::new(usize::MAX) };
}

// ---- per-layer / per-adapter attribution ----

/// One attribution slot (a layer, or an adapter). Unsharded: updated
/// once per batch (layers) or once per rider (adapters) with plain
/// relaxed adds — a handful of atomics per batch, far off the critical
/// contention path.
struct SlotStat {
    hops: AtomicU64,
    batches: AtomicU64,
    queue_ns: AtomicU64,
    compute_ns: AtomicU64,
}

impl SlotStat {
    fn new() -> SlotStat {
        SlotStat {
            hops: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            queue_ns: AtomicU64::new(0),
            compute_ns: AtomicU64::new(0),
        }
    }
}

// ---- tracing ----

/// What kind of request a trace follows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceKind {
    Single,
    Model,
    Session,
}

impl TraceKind {
    pub fn name(self) -> &'static str {
        match self {
            TraceKind::Single => "single",
            TraceKind::Model => "model",
            TraceKind::Session => "session",
        }
    }
}

/// One span event inside a trace.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TraceStage {
    /// Passed admission (layer = first hop's layer index).
    Admitted { layer: u32 },
    /// Entered the pending FIFO (once per hop, including re-entries).
    Enqueued { layer: u32 },
    /// One hop executed: the micro-batch it rode, its queue wait, and
    /// the batch's kernel time (kernel start = event time − compute_s).
    Hop { hop: u32, layer: u32, batch: u32, groups: u32, queue_s: f64, compute_s: f64 },
    /// The ticket resolved.
    Replied { ok: bool },
}

/// A span event plus its offset from admission.
#[derive(Clone, Copy, Debug)]
pub struct TraceEvent {
    pub t_s: f64,
    pub stage: TraceStage,
}

/// Hard cap on events per trace: a long session records its first
/// `MAX_TRACE_EVENTS` spans and sets `truncated` instead of growing
/// without bound.
pub const MAX_TRACE_EVENTS: usize = 256;

/// The in-flight trace buffer riding a request's `Pending` hop. Created
/// by [`Telemetry::begin_trace`] (None when telemetry is disabled — the
/// hot path then pays one branch, no allocation), finished by
/// [`Telemetry::finish_trace`].
pub struct TraceBuf {
    id: u64,
    kind: TraceKind,
    t0: Instant,
    adapter_slot: Option<u32>,
    hops: u32,
    truncated: bool,
    events: Vec<TraceEvent>,
}

impl TraceBuf {
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Record a span event at now − admission.
    pub fn event(&mut self, stage: TraceStage) {
        if self.events.len() >= MAX_TRACE_EVENTS {
            self.truncated = true;
            return;
        }
        self.events.push(TraceEvent { t_s: self.t0.elapsed().as_secs_f64(), stage });
    }

    /// Record one executed hop (numbers them 1-based internally).
    pub fn hop(&mut self, layer: u32, batch: u32, groups: u32, queue_s: f64, compute_s: f64) {
        self.hops += 1;
        let hop = self.hops;
        self.event(TraceStage::Hop { hop, layer, batch, groups, queue_s, compute_s });
    }
}

/// A finished request trace, as kept in the snapshot rings.
#[derive(Clone, Debug)]
pub struct Trace {
    pub id: u64,
    pub kind: TraceKind,
    pub ok: bool,
    pub wall_s: f64,
    pub adapter_slot: Option<u32>,
    pub truncated: bool,
    pub events: Vec<TraceEvent>,
}

impl Trace {
    /// Multi-line human rendering of the span timeline (the slow-request
    /// log and the demo print this).
    pub fn render(&self) -> String {
        use std::fmt::Write;
        let mut out = String::new();
        let verdict = if self.ok { "ok" } else { "failed" };
        let _ = write!(
            out,
            "trace #{} {} {} wall={:.3}ms",
            self.id,
            self.kind.name(),
            verdict,
            self.wall_s * 1e3
        );
        if let Some(slot) = self.adapter_slot {
            let _ = write!(out, " adapter_slot={slot}");
        }
        for ev in &self.events {
            let _ = write!(out, "\n  +{:.3}ms ", ev.t_s * 1e3);
            match ev.stage {
                TraceStage::Admitted { layer } => {
                    let _ = write!(out, "admitted layer={layer}");
                }
                TraceStage::Enqueued { layer } => {
                    let _ = write!(out, "enqueued layer={layer}");
                }
                TraceStage::Hop { hop, layer, batch, groups, queue_s, compute_s } => {
                    let _ = write!(
                        out,
                        "hop {hop} layer={layer} batch={batch} groups={groups} \
                         queue={:.3}ms kernel={:.3}ms",
                        queue_s * 1e3,
                        compute_s * 1e3
                    );
                }
                TraceStage::Replied { ok } => {
                    let _ = write!(out, "replied {}", if ok { "ok" } else { "err" });
                }
            }
        }
        if self.truncated {
            out.push_str("\n  … trace truncated");
        }
        out
    }
}

struct TraceRings {
    recent: VecDeque<Trace>,
    slow: VecDeque<Trace>,
}

// ---- options ----

/// Telemetry configuration (see `ServeEngineBuilder::telemetry`).
/// Chainable setters mirror the builder idiom used across the crate.
#[derive(Clone, Copy, Debug)]
pub struct TelemetryOptions {
    /// Master switch. Disabled = every telemetry call is one predictable
    /// branch and `begin_trace` returns None (no per-request allocation)
    /// — the baseline `bench_telemetry` measures overhead against.
    pub enabled: bool,
    /// Requests slower than this are captured in the slow ring and
    /// logged at Warn (default 250 ms).
    pub slow_threshold_s: f64,
    /// Capacity of the recent-traces ring (default 64).
    pub recent_traces: usize,
    /// Capacity of the slow-traces ring (default 32).
    pub slow_traces: usize,
    /// Adapter slots tracked individually; higher slots share one
    /// overflow row (default 64).
    pub max_tracked_adapters: usize,
}

impl Default for TelemetryOptions {
    fn default() -> TelemetryOptions {
        TelemetryOptions {
            enabled: true,
            slow_threshold_s: 0.25,
            recent_traces: 64,
            slow_traces: 32,
            max_tracked_adapters: 64,
        }
    }
}

impl TelemetryOptions {
    /// Everything off: counters, histograms, and traces all become
    /// no-ops. `EngineStats` derived from such an engine reads zero.
    pub fn disabled() -> TelemetryOptions {
        TelemetryOptions { enabled: false, ..TelemetryOptions::default() }
    }

    pub fn slow_threshold_s(mut self, s: f64) -> TelemetryOptions {
        self.slow_threshold_s = s;
        self
    }

    pub fn recent_traces(mut self, n: usize) -> TelemetryOptions {
        self.recent_traces = n;
        self
    }

    pub fn slow_traces(mut self, n: usize) -> TelemetryOptions {
        self.slow_traces = n;
        self
    }

    pub fn max_tracked_adapters(mut self, n: usize) -> TelemetryOptions {
        self.max_tracked_adapters = n;
        self
    }
}

// ---- the handle ----

/// The telemetry core. One per engine (`ServeEngine::telemetry_handle`),
/// shared by reference with the WAL and (optionally) an
/// `ArtifactStore`. All write paths are lock-free; only trace-ring
/// pushes and snapshots take the ring mutex.
pub struct Telemetry {
    enabled: bool,
    opts: TelemetryOptions,
    start: Instant,
    shard_mask: usize,
    shards: Vec<Shard>,
    layer_names: Vec<String>,
    per_layer: Vec<SlotStat>,
    /// `max_tracked_adapters` individual slots + one overflow slot.
    per_adapter: Vec<SlotStat>,
    next_trace_id: AtomicU64,
    rings: Mutex<TraceRings>,
}

impl Telemetry {
    /// Build a core sized for `shard_hint` concurrent writer threads
    /// (the engine passes its worker count) over the named layers.
    pub fn new(layer_names: Vec<String>, shard_hint: usize, opts: TelemetryOptions) -> Telemetry {
        let shards = (shard_hint.max(1) + 1).next_power_of_two().min(16);
        let n_layers = layer_names.len();
        Telemetry {
            enabled: opts.enabled,
            opts,
            start: Instant::now(),
            shard_mask: shards - 1,
            shards: (0..shards).map(|_| Shard::new()).collect(),
            layer_names,
            per_layer: (0..n_layers).map(|_| SlotStat::new()).collect(),
            per_adapter: (0..opts.max_tracked_adapters + 1).map(|_| SlotStat::new()).collect(),
            next_trace_id: AtomicU64::new(0),
            rings: Mutex::new(TraceRings { recent: VecDeque::new(), slow: VecDeque::new() }),
        }
    }

    /// A core with no layer table — for instrumenting an
    /// [`ArtifactStore`](crate::serve::artifact::ArtifactStore) or a WAL
    /// outside an engine.
    pub fn standalone() -> Telemetry {
        Telemetry::new(Vec::new(), 1, TelemetryOptions::default())
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    pub fn options(&self) -> &TelemetryOptions {
        &self.opts
    }

    /// This thread's shard: assigned round-robin at first use, then a
    /// thread-local read + mask. No hashing, no locking.
    fn shard(&self) -> &Shard {
        let pick = MY_SHARD.with(|c| {
            let v = c.get();
            if v != usize::MAX {
                v
            } else {
                let v = NEXT_SHARD.fetch_add(1, Ordering::Relaxed);
                c.set(v);
                v
            }
        });
        &self.shards[pick & self.shard_mask]
    }

    pub fn incr(&self, c: Counter) {
        self.add(c, 1);
    }

    pub fn add(&self, c: Counter, n: u64) {
        if !self.enabled || n == 0 {
            return;
        }
        self.shard().counters[c as usize].fetch_add(n, Ordering::Relaxed);
    }

    pub fn observe(&self, m: Metric, seconds: f64) {
        self.observe_ns(m, (seconds.max(0.0) * 1e9) as u64);
    }

    pub fn observe_ns(&self, m: Metric, ns: u64) {
        if !self.enabled {
            return;
        }
        self.shard().hists[m as usize].observe_ns(ns);
    }

    /// Fold one micro-batch size into the sharded running max.
    pub fn record_batch_max(&self, bs: usize) {
        if !self.enabled {
            return;
        }
        self.shard().max_batch.fetch_max(bs as u64, Ordering::Relaxed);
    }

    /// Fold one dispatch-shard queue depth (observed at push time) into
    /// the sharded running max — the backlog high-water mark of the
    /// sharded dispatcher.
    pub fn record_shard_depth(&self, depth: usize) {
        if !self.enabled {
            return;
        }
        self.shard().max_shard_depth.fetch_max(depth as u64, Ordering::Relaxed);
    }

    /// Attribute one executed micro-batch to its layer.
    pub fn layer_batch(&self, layer_idx: usize, bs: usize, queue_ns: u64, compute_ns: u64) {
        if !self.enabled {
            return;
        }
        if let Some(s) = self.per_layer.get(layer_idx) {
            s.hops.fetch_add(bs as u64, Ordering::Relaxed);
            s.batches.fetch_add(1, Ordering::Relaxed);
            s.queue_ns.fetch_add(queue_ns, Ordering::Relaxed);
            s.compute_ns.fetch_add(compute_ns, Ordering::Relaxed);
        }
    }

    /// Attribute one hop to its adapter slot (`compute_ns` should be the
    /// rider's fair share of the batch kernel, `batch kernel / batch
    /// size` — the kernel ran once for all riders).
    pub fn adapter_hop(&self, slot: u32, queue_ns: u64, compute_ns: u64) {
        if !self.enabled {
            return;
        }
        let i = (slot as usize).min(self.per_adapter.len() - 1);
        let s = &self.per_adapter[i];
        s.hops.fetch_add(1, Ordering::Relaxed);
        s.queue_ns.fetch_add(queue_ns, Ordering::Relaxed);
        s.compute_ns.fetch_add(compute_ns, Ordering::Relaxed);
    }

    /// Start a trace (None when disabled — callers thread the Option
    /// through without branching on `enabled` themselves).
    pub fn begin_trace(&self, kind: TraceKind, adapter_slot: Option<u32>) -> Option<Box<TraceBuf>> {
        if !self.enabled {
            return None;
        }
        let id = self.next_trace_id.fetch_add(1, Ordering::Relaxed) + 1;
        Some(Box::new(TraceBuf {
            id,
            kind,
            t0: Instant::now(),
            adapter_slot,
            hops: 0,
            truncated: false,
            events: Vec::with_capacity(8),
        }))
    }

    /// Finish a trace: record the end-to-end wall histogram, push the
    /// trace into the recent ring (evicting the oldest), and — when the
    /// wall time crossed the slow threshold — keep it in the slow ring
    /// too and log it at Warn through `util::logging`.
    pub fn finish_trace(&self, mut t: Box<TraceBuf>, ok: bool) {
        let wall_s = t.t0.elapsed().as_secs_f64();
        t.event(TraceStage::Replied { ok });
        self.observe(Metric::RequestWall, wall_s);
        let trace = Trace {
            id: t.id,
            kind: t.kind,
            ok,
            wall_s,
            adapter_slot: t.adapter_slot,
            truncated: t.truncated,
            events: t.events,
        };
        let slow = wall_s >= self.opts.slow_threshold_s;
        if slow {
            self.incr(Counter::SlowRequests);
            crate::warn!(
                "telemetry: slow request (wall {:.3}ms ≥ threshold {:.3}ms)\n{}",
                wall_s * 1e3,
                self.opts.slow_threshold_s * 1e3,
                trace.render()
            );
        }
        let mut dropped = false;
        {
            let mut rings = self.rings.lock().unwrap();
            if slow && self.opts.slow_traces > 0 {
                if rings.slow.len() >= self.opts.slow_traces {
                    rings.slow.pop_front();
                }
                rings.slow.push_back(trace.clone());
            }
            if self.opts.recent_traces > 0 {
                if rings.recent.len() >= self.opts.recent_traces {
                    rings.recent.pop_front();
                    dropped = true;
                }
                rings.recent.push_back(trace);
            } else {
                dropped = true;
            }
        }
        if dropped {
            self.incr(Counter::TracesDropped);
        }
    }

    /// Merge every shard (plus the attribution tables and trace rings)
    /// into one consistent-enough view. `adapter_names[slot]` decorates
    /// the per-adapter rows; pass `&[]` to label rows by slot index.
    pub fn snapshot(&self, adapter_names: &[String]) -> TelemetrySnapshot {
        let mut counters = [0u64; N_COUNTERS];
        let mut max_batch = 0u64;
        let mut max_shard_depth = 0u64;
        let mut hists: Vec<HistSnapshot> = (0..N_METRICS)
            .map(|_| HistSnapshot { buckets: vec![0; HIST_BUCKETS], count: 0, sum_s: 0.0 })
            .collect();
        let mut sums_ns = [0u64; N_METRICS];
        for shard in &self.shards {
            for (i, c) in shard.counters.iter().enumerate() {
                counters[i] += c.load(Ordering::Relaxed);
            }
            max_batch = max_batch.max(shard.max_batch.load(Ordering::Relaxed));
            max_shard_depth =
                max_shard_depth.max(shard.max_shard_depth.load(Ordering::Relaxed));
            for (m, h) in shard.hists.iter().enumerate() {
                for (b, cnt) in h.buckets.iter().enumerate() {
                    hists[m].buckets[b] += cnt.load(Ordering::Relaxed);
                }
                sums_ns[m] += h.sum_ns.load(Ordering::Relaxed);
            }
        }
        for (m, h) in hists.iter_mut().enumerate() {
            h.count = h.buckets.iter().sum();
            h.sum_s = sums_ns[m] as f64 * 1e-9;
        }
        let per_layer = self
            .per_layer
            .iter()
            .enumerate()
            .map(|(i, s)| SlotSnapshot {
                name: self.layer_names.get(i).cloned().unwrap_or_else(|| format!("layer{i}")),
                index: i,
                hops: s.hops.load(Ordering::Relaxed),
                batches: s.batches.load(Ordering::Relaxed),
                queue_s: s.queue_ns.load(Ordering::Relaxed) as f64 * 1e-9,
                compute_s: s.compute_ns.load(Ordering::Relaxed) as f64 * 1e-9,
            })
            .collect();
        let overflow = self.per_adapter.len() - 1;
        let per_adapter = self
            .per_adapter
            .iter()
            .enumerate()
            .filter(|(_, s)| s.hops.load(Ordering::Relaxed) > 0)
            .map(|(i, s)| SlotSnapshot {
                name: if i == overflow {
                    "(overflow)".to_string()
                } else {
                    adapter_names.get(i).cloned().unwrap_or_else(|| format!("slot{i}"))
                },
                index: i,
                hops: s.hops.load(Ordering::Relaxed),
                batches: s.batches.load(Ordering::Relaxed),
                queue_s: s.queue_ns.load(Ordering::Relaxed) as f64 * 1e-9,
                compute_s: s.compute_ns.load(Ordering::Relaxed) as f64 * 1e-9,
            })
            .collect();
        let (recent_traces, slow_traces) = {
            let rings = self.rings.lock().unwrap();
            (rings.recent.iter().cloned().collect(), rings.slow.iter().cloned().collect())
        };
        TelemetrySnapshot {
            uptime_s: self.start.elapsed().as_secs_f64(),
            enabled: self.enabled,
            max_batch_seen: max_batch as usize,
            max_shard_depth_seen: max_shard_depth as usize,
            counters,
            hists,
            per_layer,
            per_adapter,
            recent_traces,
            slow_traces,
        }
    }
}

// ---- snapshot ----

/// A merged histogram: per-bucket counts (non-cumulative), total count,
/// and the exact observed sum in seconds.
#[derive(Clone, Debug)]
pub struct HistSnapshot {
    buckets: Vec<u64>,
    pub count: u64,
    pub sum_s: f64,
}

impl HistSnapshot {
    /// Quantile estimate in seconds (`q` in [0, 1]): the upper bound of
    /// the bucket holding the q-th observation — within one log-linear
    /// bucket (at most 25% above the true value, the width of one
    /// sub-bucket relative to an octave's floor). 0.0 when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if cum >= target {
                let upper = if i == HIST_BUCKETS - 1 {
                    1u64 << HIST_MAX_EXP
                } else {
                    bucket_upper_ns(i)
                };
                return upper as f64 * 1e-9;
            }
        }
        (1u64 << HIST_MAX_EXP) as f64 * 1e-9
    }

    pub fn mean_s(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_s / self.count as f64
        }
    }

    /// Cumulative `(upper_bound_s, count ≤ bound)` pairs for every
    /// nonempty bucket, ending with the +Inf bucket — the Prometheus
    /// exposition rows, also usable directly by an HTTP layer.
    pub fn cumulative(&self) -> Vec<(f64, u64)> {
        let mut out = Vec::new();
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if c > 0 && i < HIST_BUCKETS - 1 {
                out.push((bucket_upper_ns(i) as f64 * 1e-9, cum));
            }
        }
        out.push((f64::INFINITY, cum));
        out
    }
}

/// Per-layer / per-adapter attribution row.
#[derive(Clone, Debug)]
pub struct SlotSnapshot {
    pub name: String,
    pub index: usize,
    pub hops: u64,
    /// Micro-batches executed at this layer (0 for adapter rows — the
    /// batch belongs to the layer; adapters count hops).
    pub batches: u64,
    pub queue_s: f64,
    pub compute_s: f64,
}

/// A point-in-time merged view of everything the engine's telemetry
/// tracks. Cheap to hold; render with
/// [`TelemetrySnapshot::render_prometheus`] or collapse to the
/// back-compat [`EngineStats`] with [`TelemetrySnapshot::engine_stats`].
#[derive(Clone, Debug)]
pub struct TelemetrySnapshot {
    pub uptime_s: f64,
    pub enabled: bool,
    pub max_batch_seen: usize,
    /// Deepest dispatch-shard backlog observed at push time (sharded
    /// dispatch; 0 under the global batcher).
    pub max_shard_depth_seen: usize,
    counters: [u64; N_COUNTERS],
    hists: Vec<HistSnapshot>,
    pub per_layer: Vec<SlotSnapshot>,
    pub per_adapter: Vec<SlotSnapshot>,
    /// Most recent finished traces, oldest first.
    pub recent_traces: Vec<Trace>,
    /// Captured slow traces, oldest first.
    pub slow_traces: Vec<Trace>,
}

impl TelemetrySnapshot {
    pub fn counter(&self, c: Counter) -> u64 {
        self.counters[c as usize]
    }

    pub fn hist(&self, m: Metric) -> &HistSnapshot {
        &self.hists[m as usize]
    }

    /// The back-compat counter view `ServeEngine::stats` returns: every
    /// field of the old mutex-guarded struct, derived. Counts are exact
    /// (they were atomic increments); the two time totals come from the
    /// histogram nanosecond sums.
    pub fn engine_stats(&self) -> EngineStats {
        EngineStats {
            requests: self.counter(Counter::SinglesOk) as usize,
            model_requests: self.counter(Counter::ModelsOk) as usize,
            session_forwards: self.counter(Counter::SessionForwards) as usize,
            hops: self.counter(Counter::Hops) as usize,
            batches: self.counter(Counter::Batches) as usize,
            max_batch_seen: self.max_batch_seen,
            mixed_batches: self.counter(Counter::MixedBatches) as usize,
            rejected: self.counter(Counter::Rejected) as usize,
            batch_panics: self.counter(Counter::BatchPanics) as usize,
            failed: self.counter(Counter::SinglesFailed) as usize,
            failed_model_requests: self.counter(Counter::ModelsFailed) as usize,
            total_queue_s: self.hist(Metric::HopQueue).sum_s,
            total_compute_s: self.hist(Metric::BatchCompute).sum_s,
        }
    }

    /// Prometheus text exposition (v0.0.4): every counter, every
    /// histogram (nonempty buckets as cumulative `_bucket{le=...}` rows
    /// plus `_sum`/`_count`), the per-layer and per-adapter attribution
    /// as labeled counters, and engine gauges. The future HTTP
    /// `/metrics` endpoint is a one-liner over this.
    pub fn render_prometheus(&self) -> String {
        use std::fmt::Write;
        let mut out = String::new();
        let _ = writeln!(out, "# HELP cloq_uptime_seconds Engine uptime.");
        let _ = writeln!(out, "# TYPE cloq_uptime_seconds gauge");
        let _ = writeln!(out, "cloq_uptime_seconds {}", self.uptime_s);
        let _ = writeln!(out, "# HELP cloq_max_batch_seen Largest micro-batch executed.");
        let _ = writeln!(out, "# TYPE cloq_max_batch_seen gauge");
        let _ = writeln!(out, "cloq_max_batch_seen {}", self.max_batch_seen);
        let _ = writeln!(
            out,
            "# HELP cloq_max_shard_depth_seen Deepest dispatch-shard backlog observed."
        );
        let _ = writeln!(out, "# TYPE cloq_max_shard_depth_seen gauge");
        let _ = writeln!(out, "cloq_max_shard_depth_seen {}", self.max_shard_depth_seen);
        for c in Counter::ALL {
            let _ = writeln!(out, "# HELP cloq_{} {}", c.name(), c.help());
            let _ = writeln!(out, "# TYPE cloq_{} counter", c.name());
            let _ = writeln!(out, "cloq_{} {}", c.name(), self.counter(c));
        }
        for m in Metric::ALL {
            let h = self.hist(m);
            let _ = writeln!(out, "# HELP cloq_{} {}", m.name(), m.help());
            let _ = writeln!(out, "# TYPE cloq_{} histogram", m.name());
            for (le, cum) in h.cumulative() {
                if le.is_infinite() {
                    let _ = writeln!(out, "cloq_{}_bucket{{le=\"+Inf\"}} {cum}", m.name());
                } else {
                    let _ = writeln!(out, "cloq_{}_bucket{{le=\"{le}\"}} {cum}", m.name());
                }
            }
            let _ = writeln!(out, "cloq_{}_sum {}", m.name(), h.sum_s);
            let _ = writeln!(out, "cloq_{}_count {}", m.name(), h.count);
        }
        let layer_rows: [(&str, &str, fn(&SlotSnapshot) -> String); 4] = [
            ("cloq_layer_hops_total", "Riders served at this layer.", |s| s.hops.to_string()),
            (
                "cloq_layer_batches_total",
                "Micro-batches executed at this layer.",
                |s| s.batches.to_string(),
            ),
            (
                "cloq_layer_queue_seconds_total",
                "Summed rider queue wait at this layer.",
                |s| s.queue_s.to_string(),
            ),
            (
                "cloq_layer_compute_seconds_total",
                "Summed kernel time at this layer.",
                |s| s.compute_s.to_string(),
            ),
        ];
        for (name, help, value) in layer_rows {
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} counter");
            for s in &self.per_layer {
                let _ =
                    writeln!(out, "{name}{{layer=\"{}\"}} {}", escape_label(&s.name), value(s));
            }
        }
        let adapter_rows: [(&str, &str, fn(&SlotSnapshot) -> String); 3] = [
            ("cloq_adapter_hops_total", "Hops attributed to this adapter.", |s| {
                s.hops.to_string()
            }),
            (
                "cloq_adapter_queue_seconds_total",
                "Summed hop queue wait attributed to this adapter.",
                |s| s.queue_s.to_string(),
            ),
            (
                "cloq_adapter_compute_seconds_total",
                "Fair-share kernel time attributed to this adapter.",
                |s| s.compute_s.to_string(),
            ),
        ];
        for (name, help, value) in adapter_rows {
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} counter");
            for s in &self.per_adapter {
                let _ = writeln!(
                    out,
                    "{name}{{adapter=\"{}\"}} {}",
                    escape_label(&s.name),
                    value(s)
                );
            }
        }
        out
    }
}

/// Prometheus label-value escaping: backslash, double quote, newline.
fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for ch in v.chars() {
        match ch {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_layout_is_monotonic_and_contains_values() {
        // Every bucket's upper bound strictly grows, and bucket_of is
        // consistent with the bounds: value < upper(bucket) and, for
        // non-underflow buckets, value >= upper(bucket - 1).
        let mut prev = 0u64;
        for i in 0..HIST_BUCKETS - 1 {
            let up = bucket_upper_ns(i);
            assert!(up > prev, "bucket {i}: {up} <= {prev}");
            prev = up;
        }
        for ns in [0u64, 1, 255, 256, 257, 1_000, 1_500, 123_456, 10u64.pow(9), u64::MAX] {
            let b = bucket_of(ns);
            assert!(ns < bucket_upper_ns(b) || b == HIST_BUCKETS - 1, "ns={ns} b={b}");
            if b > 0 {
                assert!(ns >= bucket_upper_ns(b - 1), "ns={ns} b={b}");
            }
        }
        // Relative error bound: the bucket width is ≤ 1/4 of its lower
        // bound for all mid-range buckets.
        for ns in [300u64, 1_000, 50_000, 3_000_000] {
            let b = bucket_of(ns);
            let up = bucket_upper_ns(b);
            let lo = bucket_upper_ns(b - 1);
            assert!(up - lo <= lo / 4 + 1, "bucket at {ns}: [{lo}, {up})");
        }
    }

    #[test]
    fn histogram_merges_across_threads_and_estimates_quantiles() {
        let tel = std::sync::Arc::new(Telemetry::new(vec![], 8, TelemetryOptions::default()));
        // 90 × 1ms + 10 × 100ms, observed from 4 threads so several
        // shards fill; the merged view must see all 100.
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let tel = std::sync::Arc::clone(&tel);
                std::thread::spawn(move || {
                    for i in 0..25 {
                        let ms = if (t * 25 + i) % 10 == 0 { 100.0 } else { 1.0 };
                        tel.observe(Metric::HopQueue, ms * 1e-3);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let snap = tel.snapshot(&[]);
        let h = snap.hist(Metric::HopQueue);
        assert_eq!(h.count, 100);
        let expect_sum = 90.0 * 1e-3 + 10.0 * 100e-3;
        assert!((h.sum_s - expect_sum).abs() < 1e-6, "{}", h.sum_s);
        // p50 ≈ 1ms, p99 ≈ 100ms, both within one log-linear bucket.
        let p50 = h.quantile(0.5);
        assert!(p50 >= 1e-3 && p50 <= 1.25e-3, "p50={p50}");
        let p99 = h.quantile(0.99);
        assert!(p99 >= 100e-3 && p99 <= 125e-3, "p99={p99}");
        // The cumulative rows end at +Inf with the full count.
        let cum = h.cumulative();
        assert_eq!(cum.last().unwrap().1, 100);
    }

    #[test]
    fn counters_shard_and_merge() {
        let tel = std::sync::Arc::new(Telemetry::new(vec![], 4, TelemetryOptions::default()));
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let tel = std::sync::Arc::clone(&tel);
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        tel.incr(Counter::Hops);
                    }
                    tel.record_batch_max(7);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let snap = tel.snapshot(&[]);
        assert_eq!(snap.counter(Counter::Hops), 8000);
        assert_eq!(snap.max_batch_seen, 7);
        assert_eq!(snap.counter(Counter::Batches), 0);
    }

    #[test]
    fn disabled_telemetry_is_inert() {
        let tel = Telemetry::new(vec!["l0".into()], 2, TelemetryOptions::disabled());
        tel.incr(Counter::Hops);
        tel.observe(Metric::HopQueue, 1.0);
        tel.layer_batch(0, 4, 100, 100);
        tel.adapter_hop(0, 100, 100);
        tel.record_batch_max(9);
        assert!(tel.begin_trace(TraceKind::Single, None).is_none());
        let snap = tel.snapshot(&[]);
        assert!(!snap.enabled);
        assert_eq!(snap.counter(Counter::Hops), 0);
        assert_eq!(snap.hist(Metric::HopQueue).count, 0);
        assert_eq!(snap.max_batch_seen, 0);
        assert!(snap.per_layer.iter().all(|s| s.hops == 0));
        assert!(snap.per_adapter.is_empty());
        let stats = snap.engine_stats();
        assert_eq!(stats.hops, 0);
    }

    #[test]
    fn trace_rings_evict_and_capture_slow() {
        // Threshold 0 ⇒ every request is "slow"; recent ring of 4 must
        // evict, slow ring of 2 must keep only the newest 2.
        crate::util::logging::set_level(crate::util::logging::Level::Error);
        let opts = TelemetryOptions::default()
            .slow_threshold_s(0.0)
            .recent_traces(4)
            .slow_traces(2);
        let tel = Telemetry::new(vec![], 1, opts);
        for k in 0..10u32 {
            let mut t = tel.begin_trace(TraceKind::Single, Some(k)).unwrap();
            t.event(TraceStage::Admitted { layer: 0 });
            t.hop(0, 3, 1, 1e-6, 2e-6);
            tel.finish_trace(t, true);
        }
        let snap = tel.snapshot(&[]);
        assert_eq!(snap.recent_traces.len(), 4);
        assert_eq!(snap.slow_traces.len(), 2);
        assert_eq!(snap.counter(Counter::SlowRequests), 10);
        assert_eq!(snap.counter(Counter::TracesDropped), 6);
        // Newest-last ordering; ids are process-unique and increasing.
        let ids: Vec<u64> = snap.recent_traces.iter().map(|t| t.id).collect();
        assert!(ids.windows(2).all(|w| w[0] < w[1]), "{ids:?}");
        // The span timeline survived: admitted → hop → replied.
        let tr = snap.recent_traces.last().unwrap();
        assert!(tr.ok);
        assert!(matches!(tr.events[0].stage, TraceStage::Admitted { .. }));
        assert!(
            matches!(tr.events[1].stage, TraceStage::Hop { hop: 1, batch: 3, .. }),
            "{:?}",
            tr.events[1]
        );
        assert!(matches!(tr.events.last().unwrap().stage, TraceStage::Replied { ok: true }));
        assert!(tr.render().contains("hop 1"), "{}", tr.render());
    }

    #[test]
    fn trace_buf_truncates_instead_of_growing() {
        crate::util::logging::set_level(crate::util::logging::Level::Error);
        let tel = Telemetry::new(vec![], 1, TelemetryOptions::default());
        let mut t = tel.begin_trace(TraceKind::Session, None).unwrap();
        for _ in 0..(2 * MAX_TRACE_EVENTS) {
            t.hop(0, 1, 1, 0.0, 0.0);
        }
        tel.finish_trace(t, true);
        let snap = tel.snapshot(&[]);
        let tr = snap.recent_traces.last().unwrap();
        assert!(tr.truncated);
        assert_eq!(tr.events.len(), MAX_TRACE_EVENTS);
        assert!(tr.render().contains("truncated"));
    }

    #[test]
    fn attribution_tables_index_by_slot_with_overflow() {
        let opts = TelemetryOptions::default().max_tracked_adapters(2);
        let tel = Telemetry::new(vec!["wq".into(), "wo".into()], 1, opts);
        tel.layer_batch(0, 4, 1_000, 2_000);
        tel.layer_batch(1, 2, 500, 700);
        tel.layer_batch(9, 1, 1, 1); // out of range: ignored, no panic
        tel.adapter_hop(0, 100, 10);
        tel.adapter_hop(1, 200, 20);
        tel.adapter_hop(7, 400, 40); // beyond cap → overflow slot
        let snap = tel.snapshot(&["tenant-a".into()]);
        assert_eq!(snap.per_layer.len(), 2);
        assert_eq!(snap.per_layer[0].name, "wq");
        assert_eq!(snap.per_layer[0].hops, 4);
        assert_eq!(snap.per_layer[0].batches, 1);
        assert!((snap.per_layer[1].queue_s - 500e-9).abs() < 1e-15);
        assert_eq!(snap.per_adapter.len(), 3);
        assert_eq!(snap.per_adapter[0].name, "tenant-a");
        assert_eq!(snap.per_adapter[1].name, "slot1", "unnamed slots fall back to index");
        assert_eq!(snap.per_adapter[2].name, "(overflow)");
        assert_eq!(snap.per_adapter[2].hops, 1);
    }

    #[test]
    fn engine_stats_view_maps_counters_and_sums() {
        let tel = Telemetry::new(vec![], 1, TelemetryOptions::default());
        tel.add(Counter::SinglesOk, 5);
        tel.add(Counter::Hops, 8);
        tel.add(Counter::Batches, 2);
        tel.incr(Counter::Rejected);
        tel.record_batch_max(6);
        tel.observe(Metric::HopQueue, 0.5);
        tel.observe(Metric::BatchCompute, 0.25);
        let stats = tel.snapshot(&[]).engine_stats();
        assert_eq!(stats.requests, 5);
        assert_eq!(stats.hops, 8);
        assert_eq!(stats.batches, 2);
        assert_eq!(stats.rejected, 1);
        assert_eq!(stats.max_batch_seen, 6);
        assert!((stats.total_queue_s - 0.5).abs() < 1e-6, "{}", stats.total_queue_s);
        assert!((stats.total_compute_s - 0.25).abs() < 1e-6);
        assert!((stats.mean_batch() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn prometheus_labels_escape() {
        assert_eq!(escape_label("plain"), "plain");
        assert_eq!(escape_label("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
    }
}

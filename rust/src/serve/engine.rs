//! The serving front-end: admits concurrent forward requests (each naming
//! an interned layer and, optionally, an interned adapter), coalesces them
//! into per-layer micro-batches, and executes the batches on worker
//! threads. Two dispatch cores implement that contract — a builder knob,
//! [`Dispatch`]:
//!
//! **[`Dispatch::Sharded`]** (default) — per-layer sharded queues with
//! work-stealing workers; no dedicated batcher thread, no global queue
//! lock:
//!
//! ```text
//!   submit() ────────→ shard[layer % N] ──→ worker i (owns shard i):
//!   submit_model() ↗    (N× Mutex+Condvar     drain own shard, else steal
//!        ▲               + atomic depth)      oldest batchable group
//!        └── hop re-entry: push next hop's shard ←──┘  (push-only)
//! ```
//!
//! Every layer maps to exactly ONE shard (`layer.index() % workers`), so
//! all queued traffic for a layer is adjacent in one deque and same-layer
//! micro-batches coalesce exactly as they did in the single FIFO — batch
//! formation is the same head-layer scan (`take_batch`), run on the
//! shard's deque. Worker `i` drains its own shard first; when the shard is
//! empty it STEALS the oldest batchable group from the most-loaded other
//! shard, picked by lock-free atomic depth mirrors so victim selection
//! touches no locks (each steal counts in `dispatch_steals_total`). An
//! idle worker parks on its own shard's condvar with a short timeout: the
//! timeout is the steal-liveness backstop — a parked worker is only ever
//! notified by pushes to its OWN shard, so the periodic wake is what lets
//! it notice another shard's backlog (e.g. a single-hot-layer workload
//! where every request hashes to shard 0). Workers execute the batch
//! INLINE — dispatch and kernel execution are the same thread, so at most
//! `workers` micro-batches are in flight and a saturating stream piles up
//! in the shards and coalesces, same as the global design's holdback.
//!
//! **[`Dispatch::Global`]** — the reference single-FIFO design, retained
//! as the parity baseline and the `bench_contention` comparison row:
//!
//! ```text
//!   submit() ───────→ pending FIFO ──→ batcher thread ──→ WorkerPool job
//!   submit_model() ↗   (Mutex+Condvar)  (drains ≤ max_batch  (grouped batch
//!        ▲                               same-layer hops)     kernel)
//!        └──────────── hop re-entry ←──────────────────────────┘
//! ```
//!
//! Both cores preserve every serving contract: responses are bit-identical
//! to serial execution (batch composition — coalesced, stolen, or mixed —
//! can never change a response's numbers), the adapter pin taken at
//! admission rides the whole traversal, every failure is the same typed
//! [`ServeError`], and `RequestWall.count == requests − rejected` holds in
//! telemetry.
//!
//! **The typed façade**: callers resolve names ONCE — `engine.layer("wq")`
//! → [`LayerId`], `engine.adapter("tenant-a")` → [`AdapterId`],
//! `engine.route(&[...])` → [`Route`] — and submit by handle. Admission
//! therefore does no string hashing and no string cloning; a hop carries
//! one `u32` layer index and one pinned adapter handle whose per-layer
//! lookup is an array index (resolved at registration,
//! `serve::adapters`). The name-resolving convenience path
//! ([`ServeEngine::submit_named`]) still exists for one-off calls and is
//! the "legacy stringly admission" baseline `benches/bench_serve.rs`
//! measures the typed path against. Every failure is a typed
//! [`ServeError`]; [`Ticket::wait`] returns `Result<Response, ServeError>`
//! so callers dispatch with `matches!`, not string search.
//!
//! Batch formation scans its queue head's layer and pulls every queued
//! request for that layer (up to `max_batch`), preserving the relative
//! order of the rest — arrival order stays fair across layers while the
//! kernel's row-reuse amortization (`PackedLayer::forward_batch_grouped`)
//! is harvested whenever requests pile up. **Adapter multiplexing**: each
//! request resolves its adapter to a pinned [`AdapterHandle`] at admission
//! (one version for its whole lifetime — a hot-swap can never mix old and
//! new weights in one response); the batch executor orders the micro-batch
//! so same-version requests are adjacent and runs the shared base pass
//! once, with one LoRA skinny product per adapter group. Because the
//! grouped kernel is bit-identical to serial single-adapter calls (parity
//! contract in `serve::packed`), coalescing — same-adapter or mixed — is
//! purely a throughput decision: **batch composition can never change a
//! response's numbers**.
//!
//! **Full-model pipelining** (`serve::forward`): a [`ModelRequest`] /
//! [`SessionRequest`] is decomposed into per-layer *hops*. A finished hop
//! with route left does not reply — `run_batch` pushes it back into the
//! queue at its next layer (the re-entry arrow above; under sharded
//! dispatch, directly into the next layer's shard), so hops from many
//! concurrent model requests at the same depth coalesce into one grouped
//! kernel call, exactly like independent single-layer requests would. The
//! adapter pin taken at admission rides along for the whole traversal.
//! Re-entry only ever *pushes* and notifies — no dispatch thread is ever
//! waited on from inside a batch, so hop re-entry cannot deadlock either
//! dispatch core.
//!
//! Coalescing policy: no timers. Both cores dispatch immediately while
//! workers are free (latency-first under light load) and keep at most
//! `workers` micro-batches in flight — the global batcher by an explicit
//! `in_flight` holdback, the sharded core because each worker runs its
//! batch inline — so a saturating stream of single `submit()` calls piles
//! up queued and naturally coalesces into full batches (throughput-first
//! under saturation).
//!
//! **Backpressure counts hops, not queue entries**: every admitted request
//! — single-layer or whole-model — holds exactly one *live hop slot* from
//! admission until its reply, whether that hop is queued or riding a
//! kernel. Admission rejects at `max_pending` live slots
//! ([`ServeError::Overloaded`]), so a flood of model requests cannot hide
//! from the limit by being mid-kernel when the queue is sampled. Under
//! `Global` the count lives inside the queue mutex; under `Sharded` it is
//! a lock-free atomic counter with increment-then-check admission (the
//! slot is reserved FIRST, then the closed/overload checks run, undoing
//! the reservation on refusal — sequentially-consistent ordering makes a
//! stranded admission impossible; concurrent admitters can transiently
//! overshoot the reservation count by their own number, bounding, not
//! breaking, the limit). **Shutdown drains by the same accounting**:
//! [`ServeEngine::close`] stops admissions (subsequent submits fail with
//! [`ServeError::ShuttingDown`]) while dispatch keeps draining;
//! [`ServeEngine::shutdown`] closes, then joins once the last live slot is
//! released, so every admitted traversal finishes every remaining hop. The
//! sharded drain barrier is per-shard closed+empty: each worker exits only
//! when admissions are closed AND the last live slot is gone (an empty
//! shard alone is not drained — an in-flight batch may still re-enter
//! hops), and the thread that releases the last slot after close wakes
//! every parked worker through the shards' lost-wakeup-proof broadcast.
//!
//! **Durability** (`serve::wal`): an engine built with
//! [`ServeEngineBuilder::durable`] logs every adapter register / hot-swap
//! / unregister to a crash-safe write-ahead log BEFORE applying it, and
//! replays the log through the normal registry path at [`build`] — a
//! restarted engine serves every tenant that was acknowledged before the
//! crash (bit-identical weights; `rust/tests/crash_wal.rs`). Evictions
//! are NOT logged: replay re-runs the registers in log order under the
//! same byte budget, so the recovered live set is a deterministic
//! function of the log (it may differ from the pre-crash set only in
//! which over-budget tenants were evicted, since checkout recency dies
//! with the process).
//!
//! **Handle identity**: every engine mints a process-unique token at
//! [`build`]; the [`LayerId`]s, [`Route`]s and [`AdapterId`]s it (and its
//! registry) hand out are stamped with it. Admission compares tokens
//! first — a handle minted by THIS engine is trusted by construction
//! (one integer compare instead of the O(hops) route re-walk), a token-0
//! legacy handle takes the full validation path, and a foreign engine's
//! handle is a typed [`ServeError::BadRoute`] /
//! [`ServeError::AdapterMismatch`] instead of silently addressing
//! whatever sits at that index here (`rust/tests/errors_serve.rs`).
//!
//! [`build`]: ServeEngineBuilder::build
//!
//! Every [`Response`] reports its queue wait, its micro-batch's kernel
//! time, the batch size and the adapter group count; [`EngineStats`]
//! aggregates them for the bench harness (`BENCH_serve.json` /
//! `BENCH_adapters.json` / `BENCH_forward.json`) and the demo.
//!
//! **Telemetry** (`serve::telemetry`): every admission, micro-batch, and
//! durability event records into a sharded lock-free metrics core —
//! counters, log-scale latency histograms, per-layer / per-adapter
//! attribution, and per-request span traces with automatic slow-request
//! capture. [`ServeEngine::telemetry`] returns the merged
//! [`TelemetrySnapshot`] (quantiles + `render_prometheus()`);
//! [`ServeEngine::stats`] remains the back-compat [`EngineStats`] view,
//! now *derived* from that snapshot — the per-batch stats mutex is gone
//! from the hot path entirely, and `benches/bench_telemetry.rs` gates
//! the full instrumentation overhead below 5% in CI.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use crate::linalg::Matrix;
use crate::lowrank::LoraPair;
use crate::serve::adapters::{
    AdapterHandle, AdapterId, AdapterRegistry, AdapterSet, RegisterOutcome,
};
use crate::serve::completion::{self, CompleteFn, Completion, CompletionHandle, CompletionSender};
use crate::serve::error::ServeError;
use crate::serve::forward::{
    HopOutcome, ModelRequest, ModelResponse, ModelTicket, SessionRequest, StepFn, Traversal,
};
use crate::serve::packed::{LayerId, PackedModel, Route};
use crate::serve::telemetry::{
    Counter, Metric, Telemetry, TelemetryOptions, TelemetrySnapshot, TraceBuf, TraceKind,
    TraceStage,
};
use crate::serve::wal::{FsWalFile, Wal, WalEvent, WalFile, WalOptions};
use crate::util::threadpool::{ShardedQueues, WorkerPool};

/// Which dispatch core moves admitted requests to kernel execution — a
/// [`ServeEngineBuilder::dispatch`] knob. Both cores honor every serving
/// contract (bit-parity vs serial, adapter pinning, typed errors, the
/// telemetry identities); the choice is purely about contention behavior.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Dispatch {
    /// Per-layer sharded queues with work-stealing workers (the default):
    /// admission pushes straight into `shard[layer % workers]`, each
    /// worker drains its own shard and steals the oldest batchable group
    /// from the most-loaded other shard when idle. No global queue lock —
    /// admission throughput scales with submitters (`bench_contention`).
    #[default]
    Sharded,
    /// The reference single-FIFO design: one mutex-guarded queue, a
    /// dedicated batcher thread, and a [`WorkerPool`]. Retained as the
    /// parity baseline and the `bench_contention` comparison row; pick it
    /// when strict global arrival-order batch formation matters more than
    /// admission scaling.
    Global,
}

/// Staged configuration for a [`ServeEngine`], validated at
/// [`ServeEngineBuilder::build`]. Obtain one from
/// [`ServeEngine::builder`]; every knob has a production-sane default.
///
/// ```ignore
/// let engine = ServeEngine::builder(model)
///     .workers(4)
///     .max_batch(32)
///     .max_pending(8192)
///     .adapter_budget(512 << 20)
///     .build()?;
/// ```
pub struct ServeEngineBuilder {
    model: PackedModel,
    workers: usize,
    max_batch: usize,
    max_pending: usize,
    adapter_budget_bytes: usize,
    /// Adapter WAL backing, optional compaction-snapshot backing, and
    /// the label for error messages (None = the registry is in-memory
    /// only).
    wal: Option<(Box<dyn WalFile>, Option<Box<dyn WalFile>>, String)>,
    wal_opts: WalOptions,
    telemetry: TelemetryOptions,
    dispatch: Dispatch,
}

impl std::fmt::Debug for ServeEngineBuilder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ServeEngineBuilder")
            .field("workers", &self.workers)
            .field("max_batch", &self.max_batch)
            .field("max_pending", &self.max_pending)
            .field("adapter_budget_bytes", &self.adapter_budget_bytes)
            .field("durable", &self.wal.as_ref().map(|(_, _, label)| label.clone()))
            .field("dispatch", &self.dispatch)
            .finish_non_exhaustive()
    }
}

impl ServeEngineBuilder {
    /// Kernel workers executing micro-batches (default 2). Under
    /// [`Dispatch::Sharded`] this is also the shard count — each worker
    /// owns one queue shard.
    pub fn workers(mut self, n: usize) -> Self {
        self.workers = n;
        self
    }

    /// Select the dispatch core (default [`Dispatch::Sharded`]); see the
    /// module docs for the two pipelines. Validated with the rest of the
    /// configuration at [`ServeEngineBuilder::build`].
    pub fn dispatch(mut self, d: Dispatch) -> Self {
        self.dispatch = d;
        self
    }

    /// Coalescing cap: at most this many requests per micro-batch
    /// (default 16).
    pub fn max_batch(mut self, n: usize) -> Self {
        self.max_batch = n;
        self
    }

    /// Admission backpressure (default 4096): the cap on LIVE HOP SLOTS —
    /// requests admitted but not yet answered, whether queued in the FIFO
    /// or riding a kernel (a multi-hop model request holds one slot for
    /// its whole traversal). Arrivals beyond it are rejected with
    /// [`ServeError::Overloaded`] instead of growing the queue (and its
    /// buffered activations) without bound.
    pub fn max_pending(mut self, n: usize) -> Self {
        self.max_pending = n;
        self
    }

    /// Byte budget for the adapter registry's LRU cache (default
    /// unbounded; pinned adapters are exempt — see
    /// [`AdapterRegistry::new`]).
    pub fn adapter_budget(mut self, bytes: usize) -> Self {
        self.adapter_budget_bytes = bytes;
        self
    }

    /// Make the adapter registry crash-safe: every register / hot-swap /
    /// unregister is logged to `dir/adapters.wal` BEFORE it is applied,
    /// and [`ServeEngineBuilder::build`] replays the log so a restarted
    /// engine serves every tenant acknowledged before the crash.
    /// Compaction writes the live state into `dir/adapters.snp` and
    /// truncates the log, so boot replay stays O(live + tail) however
    /// much the registry churns. See the module docs' durability section
    /// and `serve::wal` for the format and recovery contract.
    pub fn durable(mut self, dir: impl Into<std::path::PathBuf>) -> Self {
        let dir = dir.into();
        let path = dir.join("adapters.wal");
        let snap = dir.join("adapters.snp");
        let label = path.display().to_string();
        self.wal =
            Some((Box::new(FsWalFile::at(path)), Some(Box::new(FsWalFile::at(snap))), label));
        self
    }

    /// Durability over an injected [`WalFile`] — the fault-injection
    /// seam: `rust/tests/crash_wal.rs` passes files that truncate, tear,
    /// or duplicate at arbitrary byte offsets. `label` names the log in
    /// typed errors. No compaction snapshot: compaction rewrites the log
    /// in place, exactly the behavior the crash suite pins down.
    pub fn durable_wal(mut self, file: Box<dyn WalFile>, label: &str) -> Self {
        self.wal = Some((file, None, label.to_string()));
        self
    }

    /// [`ServeEngineBuilder::durable_wal`] plus an injected compaction
    /// snapshot file — the fault-injection seam for the snapshot path.
    pub fn durable_wal_snapshotted(
        mut self,
        file: Box<dyn WalFile>,
        snap: Box<dyn WalFile>,
        label: &str,
    ) -> Self {
        self.wal = Some((file, Some(snap), label.to_string()));
        self
    }

    /// Tune WAL fsync batching and compaction (no effect without
    /// [`ServeEngineBuilder::durable`] / `durable_wal`).
    pub fn wal_options(mut self, opts: WalOptions) -> Self {
        self.wal_opts = opts;
        self
    }

    /// Tune (or disable) the telemetry subsystem: sharded counters and
    /// latency histograms, per-layer/per-adapter attribution, and
    /// request tracing with slow-request capture. Enabled by default
    /// with production-sane knobs; [`TelemetryOptions::disabled`] turns
    /// every instrument into a no-op (the overhead baseline
    /// `benches/bench_telemetry.rs` measures against). See
    /// `serve::telemetry`.
    pub fn telemetry(mut self, opts: TelemetryOptions) -> Self {
        self.telemetry = opts;
        self
    }

    /// Validate the configuration and start the engine's dispatch core —
    /// shard-owning workers under [`Dispatch::Sharded`], the batcher
    /// thread + worker pool under [`Dispatch::Global`]. Zero-valued knobs
    /// and duplicate layer names are [`ServeError::InvalidConfig`] —
    /// reported here, once, instead of panicking mid-request.
    pub fn build(self) -> Result<ServeEngine, ServeError> {
        fn at_least_one(what: &str, v: usize) -> Result<(), ServeError> {
            if v == 0 {
                return Err(ServeError::InvalidConfig {
                    detail: format!("engine config: {what} must be at least 1 (got 0)"),
                });
            }
            Ok(())
        }
        at_least_one("workers", self.workers)?;
        at_least_one("max_batch", self.max_batch)?;
        at_least_one("max_pending", self.max_pending)?;
        at_least_one("adapter_budget", self.adapter_budget_bytes)?;
        if self.model.layers.is_empty() {
            return Err(ServeError::InvalidConfig {
                detail: "engine config: the served model has no layers".to_string(),
            });
        }
        let mut index = std::collections::HashMap::with_capacity(self.model.layers.len());
        for (i, l) in self.model.layers.iter().enumerate() {
            // Unique names are a serving invariant (the artifact loaders
            // enforce it on untrusted bytes; this guards hand-built
            // models) — with duplicates, name-addressed resolution would
            // be ambiguous.
            if index.insert(l.name.clone(), i).is_some() {
                return Err(ServeError::InvalidConfig {
                    detail: format!("engine config: duplicate layer name '{}'", l.name),
                });
            }
        }
        let model = Arc::new(self.model);
        let registry =
            Arc::new(AdapterRegistry::new(Arc::clone(&model), self.adapter_budget_bytes));
        // One telemetry core per engine, shared by every admission path,
        // kernel worker, and the WAL. Shard count scales with the worker
        // count so concurrent batch completions don't contend on one
        // cache line (see `serve::telemetry`).
        let telemetry = Arc::new(Telemetry::new(
            model.layers.iter().map(|l| l.name.clone()).collect(),
            self.workers,
            self.telemetry,
        ));
        // Durable mode: replay the log through the normal registry path
        // BEFORE the batcher starts, so the first admitted request already
        // sees every recovered tenant. Replay failures are typed build
        // errors (a log from a different model's engine is a shape
        // mismatch, not a panic mid-request).
        let wal = match self.wal {
            None => None,
            Some((file, snap, label)) => {
                let (mut wal, events) = match snap {
                    Some(snap) => Wal::open_snapshotted(file, snap, &label, self.wal_opts)?,
                    None => Wal::open(file, &label, self.wal_opts)?,
                };
                wal.attach_telemetry(Arc::clone(&telemetry));
                telemetry.add(Counter::WalReplayEvents, events.len() as u64);
                for ev in events {
                    match ev {
                        WalEvent::Register(set) => {
                            registry.register(set)?;
                        }
                        WalEvent::Unregister(id) => match registry.unregister(&id) {
                            // The budget may have evicted the id earlier in
                            // THIS replay; the unregister is then already
                            // honored.
                            Ok(()) | Err(ServeError::UnknownAdapter { .. }) => {}
                            Err(e) => return Err(e),
                        },
                    }
                }
                Some(Mutex::new(wal))
            }
        };
        let dispatcher = match self.dispatch {
            Dispatch::Global => Dispatcher::Global {
                state: Mutex::new(QueueState {
                    pending: VecDeque::new(),
                    open: true,
                    in_flight: 0,
                    live: 0,
                }),
                cv: Condvar::new(),
                pool: Arc::new(WorkerPool::new(self.workers)),
            },
            Dispatch::Sharded => Dispatcher::Sharded {
                shards: ShardedQueues::new(self.workers),
                live: AtomicUsize::new(0),
            },
        };
        let shared = Arc::new(Shared {
            model: Arc::clone(&model),
            index,
            registry,
            wal,
            token: crate::serve::packed::next_identity_token(),
            adapter_budget: self.adapter_budget_bytes,
            max_batch: self.max_batch,
            max_pending: self.max_pending,
            workers: self.workers,
            dispatcher,
            telemetry,
        });
        let threads = match self.dispatch {
            Dispatch::Global => {
                let shared = Arc::clone(&shared);
                vec![std::thread::spawn(move || batcher_loop(shared))]
            }
            Dispatch::Sharded => (0..self.workers)
                .map(|me| {
                    let shared = Arc::clone(&shared);
                    std::thread::spawn(move || shard_worker_loop(shared, me))
                })
                .collect(),
        };
        Ok(ServeEngine { shared, threads })
    }
}

/// One forward request for [`ServeEngine::submit_all`]: which layer, which
/// adapter (`None` = base only), and the input activation. Layer and
/// adapter are interned handles — building a `Request` allocates nothing
/// beyond its activation.
#[derive(Clone, Debug)]
pub struct Request {
    pub layer: LayerId,
    pub adapter: Option<AdapterId>,
    pub x: Vec<f64>,
}

impl Request {
    /// Base-only request (no adapter delta).
    pub fn base(layer: LayerId, x: Vec<f64>) -> Request {
        Request { layer, adapter: None, x }
    }

    /// Request routed through the interned adapter.
    pub fn with_adapter(layer: LayerId, adapter: AdapterId, x: Vec<f64>) -> Request {
        Request { layer, adapter: Some(adapter), x }
    }
}

/// One served forward result plus its latency breakdown.
#[derive(Clone, Debug)]
pub struct Response {
    pub y: Vec<f64>,
    /// Admission → micro-batch formation.
    pub queue_s: f64,
    /// Kernel time of the micro-batch this request rode in.
    pub compute_s: f64,
    /// Size of that micro-batch.
    pub batch_size: usize,
    /// Distinct adapter groups (incl. the base-only group) in that batch —
    /// 1 means the batch was adapter-uniform.
    pub adapter_groups: usize,
    /// This request's telemetry trace id (0 when tracing is disabled);
    /// look the span timeline up in `TelemetrySnapshot::recent_traces`.
    pub trace_id: u64,
}

/// Aggregate engine counters (snapshot via [`ServeEngine::stats`]).
/// Invariant: every submission resolves exactly once and lands in
/// exactly one counter — single-layer requests in `requests` (served),
/// `rejected`, or `failed` (single rider of a panicked batch);
/// model/session requests in `model_requests`, `rejected`, or
/// `failed_model_requests` — so the sum of those five counters
/// (`rejected` is shared by both request kinds) equals the number of
/// submissions whose tickets have resolved.
#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    /// Single-layer requests served successfully.
    pub requests: usize,
    /// Model/session requests answered successfully.
    pub model_requests: usize,
    /// Full-model forward passes completed by traversals (a session
    /// contributes one per step it ran).
    pub session_forwards: usize,
    /// Riders served across all successful micro-batches — single-layer
    /// requests AND traversal hops (`hops / batches` is the real batch
    /// fullness under pipelining).
    pub hops: usize,
    pub batches: usize,
    pub max_batch_seen: usize,
    /// Micro-batches that mixed more than one adapter group (served via
    /// the grouped kernel's per-adapter skinny products).
    pub mixed_batches: usize,
    /// Requests refused at admission (unknown layer, wrong width, unknown
    /// adapter, adapter without the layer, broken route, overload).
    pub rejected: usize,
    /// Micro-batches whose kernel panicked (the workers survive).
    pub batch_panics: usize,
    /// SINGLE-LAYER riders of panicked batches; each resolved with a
    /// [`ServeError::WorkerPanic`] naming the layer. Traversal riders of
    /// the same batch count in `failed_model_requests` instead, keeping
    /// the counters disjoint.
    pub failed: usize,
    /// Model/session requests answered with an error (kernel panic on one
    /// of their hops, step-fn panic, or misshapen step output).
    pub failed_model_requests: usize,
    pub total_queue_s: f64,
    pub total_compute_s: f64,
}

impl EngineStats {
    /// Mean riders per successful micro-batch (hops include single-layer
    /// requests, so this is unchanged for non-pipelined workloads).
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.hops as f64 / self.batches as f64
        }
    }

    pub fn mean_queue_s(&self) -> f64 {
        if self.hops == 0 {
            0.0
        } else {
            self.total_queue_s / self.hops as f64
        }
    }
}

/// Handle to a submitted request; resolves to its [`Response`] or a typed
/// [`ServeError`]. Implements [`Completion`] — poll with
/// [`try_wait`](Completion::try_wait) or attach a callback with
/// [`on_complete`](Completion::on_complete) instead of parking a thread.
pub struct Ticket {
    cell: CompletionHandle<Response>,
}

impl Ticket {
    /// Block until the engine answers. An engine that dropped before
    /// answering reports [`ServeError::ShuttingDown`].
    pub fn wait(self) -> Result<Response, ServeError> {
        self.cell.wait()
    }

    /// [`wait`](Ticket::wait) with a deadline: [`ServeError::Timeout`]
    /// once `timeout` elapses with no reply.
    ///
    /// The deadline is a CALLER-side contract only — the request is not
    /// cancelled. It still holds its live backpressure slot, still rides
    /// its micro-batch, and still counts in `requests` / telemetry when
    /// it completes; its reply is dropped because this ticket (the only
    /// receiver) is consumed. Use it to bound caller latency, not engine
    /// load.
    pub fn wait_timeout(self, timeout: std::time::Duration) -> Result<Response, ServeError> {
        self.cell.wait_timeout(timeout)
    }
}

impl Completion for Ticket {
    type Output = Response;

    fn try_wait(&mut self) -> Option<Result<Response, ServeError>> {
        self.cell.try_take()
    }

    fn on_complete(self, f: CompleteFn<Response>) {
        self.cell.on_complete(f);
    }

    fn wait(self) -> Result<Response, ServeError> {
        Ticket::wait(self)
    }

    fn wait_timeout(self, timeout: std::time::Duration) -> Result<Response, ServeError> {
        Ticket::wait_timeout(self, timeout)
    }
}

/// How a hop replies when its work is done.
enum HopKind {
    /// Single-layer request: reply with a [`Response`] after this hop.
    Single { tx: CompletionSender<Response> },
    /// Model/session traversal: consult [`Traversal::absorb_hop`] — it
    /// either re-enters the FIFO or replies with a [`ModelResponse`].
    Traversal(Box<Traversal>),
}

struct Pending {
    layer: LayerId,
    /// Pinned at admission; the pin lives until the response is sent —
    /// across EVERY hop of a traversal — so eviction/unregister can never
    /// pull the weights out from under a queued or in-flight request, and
    /// a hot-swap can never mix versions inside one traversal.
    adapter: Option<AdapterHandle>,
    /// The adapter's interned slot index, copied at admission for
    /// per-adapter telemetry attribution (the pinned handle does not
    /// expose its slot).
    adapter_slot: Option<u32>,
    x: Vec<f64>,
    t_in: Instant,
    /// In-flight span trace riding this hop (None when tracing is
    /// disabled). Travels with the request across every hop of a
    /// traversal; finished when the ticket resolves.
    trace: Option<Box<TraceBuf>>,
    kind: HopKind,
}

struct QueueState {
    pending: VecDeque<Pending>,
    open: bool,
    /// Micro-batches dispatched but not yet finished — the batcher holds
    /// back while this reaches the worker count (see the module docs'
    /// coalescing policy).
    in_flight: usize,
    /// Live hop slots: admitted requests (single or traversal) not yet
    /// answered, queued OR riding a kernel. Backpressure rejects at
    /// `max_pending` of these; shutdown drains until it reaches zero.
    live: usize,
}

/// Runtime state of the chosen dispatch core ([`Dispatch`], fixed at
/// build). Every queue-touching operation (`try_enqueue`, `submit_all`,
/// `complete_batch`, `close`) branches on this once; the batch execution
/// path (`run_batch`) is shared by both arms.
enum Dispatcher {
    /// Single FIFO + batcher thread + [`WorkerPool`] — the reference
    /// implementation. `state.live`/`state.open` under the mutex are the
    /// backpressure and drain accounting.
    Global { state: Mutex<QueueState>, cv: Condvar, pool: Arc<WorkerPool> },
    /// Work-stealing shard-per-worker dispatch. Admission state is
    /// lock-free: `shards.is_closed()` is the open/closed flag, `live` the
    /// hop-slot counter (both sequentially consistent — the drain proof in
    /// the module docs depends on the total order).
    Sharded { shards: ShardedQueues<Pending>, live: AtomicUsize },
}

struct Shared {
    model: Arc<PackedModel>,
    /// Name → layer index, built once so `ServeEngine::layer` /
    /// `submit_named` resolve in O(1); the typed submit path never touches
    /// it.
    index: std::collections::HashMap<String, usize>,
    registry: Arc<AdapterRegistry>,
    /// Adapter write-ahead log (durable mode only). Locked across
    /// log-then-apply so the log's op order IS the order the registry
    /// observed — replay reconstructs exactly the live state.
    wal: Option<Mutex<Wal>>,
    /// This engine's identity token: stamped into every [`LayerId`] /
    /// [`Route`] it mints, compared first at admission (module docs).
    token: u64,
    /// The registry's byte budget, kept for pre-log validation in durable
    /// mode (nothing unreplayable may reach the log).
    adapter_budget: usize,
    max_batch: usize,
    max_pending: usize,
    workers: usize,
    dispatcher: Dispatcher,
    /// Sharded metrics + tracing core. NEVER behind a queue mutex: the
    /// hot path records through relaxed atomics only (`serve::telemetry`).
    telemetry: Arc<Telemetry>,
}

impl Shared {
    /// Layer → owning shard. Total and static, so every hop of a layer —
    /// fresh admission or traversal re-entry — lands in the same deque
    /// and stays coalescible.
    fn shard_of(&self, layer: LayerId) -> usize {
        layer.index() % self.workers
    }

    /// Sharded-dispatch push: route to the layer's shard, record the
    /// resulting depth, and nudge a neighboring worker when the backlog
    /// outgrows one batch (an unlocked hint — the park timeout is the
    /// guarantee, this just shortens the idle window).
    fn push_sharded(&self, shards: &ShardedQueues<Pending>, p: Pending) {
        let shard = self.shard_of(p.layer);
        let depth = shards.push(shard, p);
        self.telemetry.record_shard_depth(depth);
        if depth > self.max_batch && self.workers > 1 {
            shards.assist((shard + 1) % self.workers);
        }
    }
}

/// The serving engine: adapter-multiplexed batching front-end over ONE
/// packed base [`PackedModel`] and many registered [`AdapterSet`]s, with
/// single-layer, full-model, and session request shapes. Construct via
/// [`ServeEngine::builder`].
pub struct ServeEngine {
    shared: Arc<Shared>,
    /// The dispatch core's threads: the single batcher under
    /// [`Dispatch::Global`], the shard-owning workers under
    /// [`Dispatch::Sharded`]. Joined (after `close`) by shutdown/drop.
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl ServeEngine {
    /// Stage a new engine over `model` with default knobs; see
    /// [`ServeEngineBuilder`] for the dials and their validation.
    pub fn builder(model: PackedModel) -> ServeEngineBuilder {
        ServeEngineBuilder {
            model,
            workers: 2,
            max_batch: 16,
            max_pending: 4096,
            adapter_budget_bytes: usize::MAX,
            wal: None,
            wal_opts: WalOptions::default(),
            telemetry: TelemetryOptions::default(),
            dispatch: Dispatch::default(),
        }
    }

    /// The served model (shapes, names, layer order).
    pub fn model(&self) -> &PackedModel {
        &self.shared.model
    }

    /// Intern a layer name: resolve once, submit by [`LayerId`] forever.
    /// The id is stamped with this engine's identity token, so admission
    /// trusts it with one integer compare (module docs).
    pub fn layer(&self, name: &str) -> Result<LayerId, ServeError> {
        self.shared
            .index
            .get(name)
            .map(|&i| LayerId::bound(i, self.shared.token))
            .ok_or_else(|| ServeError::UnknownLayer { layer: name.to_string() })
    }

    /// Resolve and validate an ordered forward route of layer names into a
    /// reusable [`Route`] (chainability checked here, once — see
    /// [`PackedModel::validate_route`]).
    pub fn route<S: AsRef<str>>(&self, names: &[S]) -> Result<Route, ServeError> {
        let mut ids = Vec::with_capacity(names.len());
        for name in names {
            ids.push(self.layer(name.as_ref())?);
        }
        self.shared.model.validate_route(&ids)?;
        Ok(Route::from_validated_bound(ids, self.shared.token))
    }

    /// Intern a registered adapter's id: resolve once, submit by
    /// [`AdapterId`] forever. The handle stays stable across hot-swaps
    /// (and even unregister/re-register of the same id).
    pub fn adapter(&self, id: &str) -> Result<AdapterId, ServeError> {
        self.shared
            .registry
            .resolve(id)
            .ok_or_else(|| ServeError::UnknownAdapter { adapter: id.to_string() })
    }

    /// Validate `set` against the served model's shapes and register it
    /// (hot-swapping any same-id predecessor; see the registry docs). The
    /// outcome carries the interned [`AdapterId`] for typed submission.
    /// In durable mode the operation is WAL-logged before it is applied:
    /// once this returns `Ok`, a crash-and-restart still serves the set.
    pub fn register_adapter(&self, set: AdapterSet) -> Result<RegisterOutcome, ServeError> {
        let Some(w) = &self.shared.wal else {
            return self.shared.registry.register(set);
        };
        // Pre-validate everything `register` could refuse, so nothing
        // unreplayable ever reaches the log (a logged-but-refused op
        // would fail the NEXT boot's replay).
        set.check_against(self.shared.registry.model())?;
        let bytes = set.bytes();
        if bytes > self.shared.adapter_budget {
            return Err(ServeError::InvalidConfig {
                detail: format!(
                    "adapter '{}': {bytes} bytes exceed the whole registry budget of {} \
                     bytes",
                    set.id(),
                    self.shared.adapter_budget
                ),
            });
        }
        // Log-then-apply under ONE wal lock: log order == apply order, so
        // replay reconstructs exactly the state the registry held. A crash
        // between the two replays the op — durability errs toward
        // remembering an acknowledged register, never forgetting one.
        //
        // GROUP COMMIT: the fsync is NOT issued under the append lock.
        // Append + apply, release the lock, then re-acquire it to commit
        // through this op's sequence number. While one thread fsyncs,
        // others append behind it and queue on the lock; the first of
        // them to run `commit_through` advances the durable watermark
        // past EVERY appended op, and the rest return without touching
        // the disk — N concurrent registers cost one fsync, not N. The
        // ack still happens after the commit, so acknowledged ⇒ durable
        // holds (`rust/tests/crash_wal.rs`).
        let (seq, applied) = {
            let mut wal = w.lock().unwrap();
            let seq = wal.append_register(&set)?;
            (seq, self.shared.registry.register(set))
        };
        let mut wal = w.lock().unwrap();
        wal.commit_through(seq)?;
        applied
    }

    /// Remove the adapter and DRAIN it: blocks until every request pinned
    /// to any version of it (queued or in-flight, including versions
    /// superseded by hot-swaps) has been answered. The pin drain alone is
    /// the full barrier: a kernel job's weight borrows are dropped BEFORE
    /// its riders' pins are released (`run_batch` drops the slot table,
    /// sends the responses, then drops the handles), so once the last pin
    /// is gone no job can still be touching the weights — and unrelated
    /// tenants' traffic never delays the retirement (a global pool
    /// quiescence wait here would starve under sustained load). A
    /// traversal's pin spans its whole route, so the drain also outwaits
    /// every remaining hop of model requests on the adapter. New
    /// submissions naming the id are rejected from the moment this is
    /// called.
    pub fn unregister_adapter(&self, id: &str) -> Result<(), ServeError> {
        let Some(w) = &self.shared.wal else {
            return self.shared.registry.unregister(id);
        };
        let (seq, applied) = {
            let mut wal = w.lock().unwrap();
            // Only live ids reach the log (replay drops unknown-id
            // unregisters defensively, but a clean writer never emits one).
            if !self.shared.registry.contains(id) {
                return Err(ServeError::UnknownAdapter { adapter: id.to_string() });
            }
            let seq = wal.append_unregister(id)?;
            // Holding the wal lock through the drain keeps log order ==
            // apply order; the drain only waits on request pins, which
            // never touch the WAL, so this cannot deadlock.
            (seq, self.shared.registry.unregister(id))
        };
        // Group-committed like registers (see `register_adapter`): the
        // caller is only acked durable after `commit_through`, which
        // piggybacks on any fsync a concurrent op already issued.
        let mut wal = w.lock().unwrap();
        wal.commit_through(seq)?;
        applied
    }

    /// The adapter registry (checkout/stats access for diagnostics and
    /// tests). The registry is bound to the served model, so even direct
    /// registrations through this accessor are shape-validated.
    pub fn registry(&self) -> &AdapterRegistry {
        &self.shared.registry
    }

    /// Admit one forward request by interned handles — the hot path: no
    /// hashing, no string clones. Invalid requests (foreign layer id,
    /// wrong input length, unknown adapter) resolve immediately with a
    /// typed error — they never occupy queue space.
    pub fn submit(&self, layer: LayerId, adapter: Option<AdapterId>, x: Vec<f64>) -> Ticket {
        let (tx, cell) = completion::channel();
        match self.admit(layer, adapter, x, &tx) {
            Ok(p) => {
                if let Err((p, e)) = self.try_enqueue(p) {
                    self.reject_pending(p, e);
                }
            }
            Err(e) => self.reject(&tx, e),
        }
        Ticket { cell }
    }

    /// Name-resolving convenience submit: looks the layer and adapter up
    /// per call (one hash each), then runs the typed path. Use
    /// [`ServeEngine::layer`] / [`ServeEngine::adapter`] +
    /// [`ServeEngine::submit`] on hot paths — `bench_serve`'s
    /// submission-overhead row measures the difference.
    pub fn submit_named(&self, layer: &str, adapter: Option<&str>, x: Vec<f64>) -> Ticket {
        let resolved = self.layer(layer).and_then(|lid| {
            let aid = match adapter {
                None => None,
                Some(name) => Some(self.adapter(name)?),
            };
            Ok((lid, aid))
        });
        match resolved {
            Ok((lid, aid)) => self.submit(lid, aid, x),
            Err(e) => {
                let (tx, cell) = completion::channel();
                self.reject(&tx, e);
                Ticket { cell }
            }
        }
    }

    /// Admit one full-model forward: the input flows through every layer
    /// of `req.route` in order, each hop coalescing with whatever other
    /// traffic is at that layer. Bit-identical to the caller-driven serial
    /// reference ([`crate::serve::forward::forward_route_serial`]) — see
    /// the parity contract in `serve::forward`.
    pub fn submit_model(&self, req: ModelRequest) -> ModelTicket {
        let (tx, cell) = completion::channel();
        match self.admit_traversal(&req.route, req.adapter, req.x, 1, None, &tx) {
            Ok(p) => {
                if let Err((p, e)) = self.try_enqueue(p) {
                    self.reject_pending(p, e);
                }
            }
            Err(e) => self.reject_model(&tx, e),
        }
        ModelTicket::new(cell)
    }

    /// Admit a multi-step session: up to `req.steps` sequential full-model
    /// forwards with `req.step` bridging each pair (the autoregressive-
    /// decode shape), all inside the engine so consecutive steps keep
    /// coalescing with concurrent traffic. The adapter is pinned once for
    /// the whole session.
    pub fn submit_session(&self, req: SessionRequest) -> ModelTicket {
        let (tx, cell) = completion::channel();
        let admitted =
            self.admit_traversal(&req.route, req.adapter, req.x0, req.steps, Some(req.step), &tx);
        match admitted {
            Ok(p) => {
                if let Err((p, e)) = self.try_enqueue(p) {
                    self.reject_pending(p, e);
                }
            }
            Err(e) => self.reject_model(&tx, e),
        }
        ModelTicket::new(cell)
    }

    /// Start a token-level generation: tokenize `req.prompt`, prefill the
    /// session state, and drive an autoregressive decode loop through the
    /// batcher — sampling, stop conditions, and per-token streaming per
    /// [`crate::serve::generate`]'s module docs. Returns immediately; the
    /// [`GenTicket`] is a non-blocking [`crate::serve::Completion`] both
    /// per token ([`GenTicket::next_token`]) and for the final
    /// [`crate::serve::generate::GenResponse`].
    ///
    /// [`GenTicket`]: crate::serve::generate::GenTicket
    /// [`GenTicket::next_token`]: crate::serve::generate::GenTicket::next_token
    pub fn generate(
        &self,
        req: crate::serve::generate::GenRequest,
    ) -> crate::serve::generate::GenTicket {
        crate::serve::generate::start(self, req)
    }

    /// Admit a burst of requests atomically per queue: dispatch cannot
    /// observe a partially-enqueued burst (one lock hold for the global
    /// FIFO; one per shard under sharded dispatch), so same-layer requests
    /// in the burst are guaranteed to be coalescible (up to `max_batch`).
    pub fn submit_all(&self, reqs: Vec<Request>) -> Vec<Ticket> {
        let mut tickets = Vec::with_capacity(reqs.len());
        let mut admitted = Vec::with_capacity(reqs.len());
        for req in reqs {
            let (tx, cell) = completion::channel();
            match self.admit(req.layer, req.adapter, req.x, &tx) {
                Ok(mut p) => {
                    if let Some(t) = p.trace.as_deref_mut() {
                        t.event(TraceStage::Enqueued { layer: p.layer.index() as u32 });
                    }
                    admitted.push(p);
                }
                Err(e) => self.reject(&tx, e),
            }
            tickets.push(Ticket { cell });
        }
        match &self.shared.dispatcher {
            Dispatcher::Global { state, cv, .. } => {
                let (overflow, closed) = {
                    let mut st = state.lock().unwrap();
                    let room = if st.open {
                        self.shared.max_pending.saturating_sub(st.live)
                    } else {
                        0
                    };
                    let overflow =
                        if admitted.len() > room { admitted.split_off(room) } else { Vec::new() };
                    st.live += admitted.len();
                    st.pending.extend(admitted);
                    (overflow, !st.open)
                };
                for p in overflow {
                    let e = if closed {
                        ServeError::ShuttingDown
                    } else {
                        ServeError::Overloaded { max_pending: self.shared.max_pending }
                    };
                    self.reject_pending(p, e);
                }
                cv.notify_one();
            }
            Dispatcher::Sharded { shards, live } => {
                // Per-request slot reservation in burst order (same
                // increment-then-check protocol as `try_enqueue`), but ONE
                // push per shard: each shard's share of the burst lands
                // under a single lock hold, so same-layer requests in the
                // burst stay adjacent and coalescible, matching the global
                // path's one-lock guarantee.
                let mut per_shard: Vec<Vec<Pending>> =
                    (0..shards.shards()).map(|_| Vec::new()).collect();
                for p in admitted {
                    let prev = live.fetch_add(1, Ordering::SeqCst);
                    if shards.is_closed() {
                        live.fetch_sub(1, Ordering::SeqCst);
                        self.reject_pending(p, ServeError::ShuttingDown);
                    } else if prev >= self.shared.max_pending {
                        live.fetch_sub(1, Ordering::SeqCst);
                        self.reject_pending(
                            p,
                            ServeError::Overloaded { max_pending: self.shared.max_pending },
                        );
                    } else {
                        per_shard[self.shared.shard_of(p.layer)].push(p);
                    }
                }
                for (i, group) in per_shard.into_iter().enumerate() {
                    if group.is_empty() {
                        continue;
                    }
                    let depth = shards.push_all(i, group);
                    self.shared.telemetry.record_shard_depth(depth);
                    if depth > self.shared.max_batch && self.shared.workers > 1 {
                        shards.assist((i + 1) % self.shared.workers);
                    }
                }
            }
        }
        tickets
    }

    fn reject(&self, tx: &CompletionSender<Response>, e: ServeError) {
        self.shared.telemetry.incr(Counter::Rejected);
        let _ = tx.send(Err(e));
    }

    fn reject_model(&self, tx: &CompletionSender<ModelResponse>, e: ServeError) {
        self.shared.telemetry.incr(Counter::Rejected);
        let _ = tx.send(Err(e));
    }

    /// Resolve an already-admitted hop with an admission-stage error (the
    /// queue refused it), whatever its reply channel type. The trace is
    /// DROPPED unfinished: a rejected request never ran, so it must not
    /// observe a request-wall latency or occupy a ring slot — rejections
    /// are visible only through the `Rejected` counter.
    fn reject_pending(&self, p: Pending, e: ServeError) {
        self.shared.telemetry.incr(Counter::Rejected);
        match p.kind {
            HopKind::Single { tx } => {
                let _ = tx.send(Err(e));
            }
            HopKind::Traversal(tr) => {
                tr.fail(e);
            }
        }
    }

    /// Enqueue under the hop-aware backpressure limit. On refusal the hop
    /// comes back so the caller can resolve its ticket with the error.
    fn try_enqueue(&self, mut p: Pending) -> Result<(), (Pending, ServeError)> {
        if let Some(t) = p.trace.as_deref_mut() {
            t.event(TraceStage::Enqueued { layer: p.layer.index() as u32 });
        }
        match &self.shared.dispatcher {
            Dispatcher::Global { state, cv, .. } => {
                {
                    let mut st = state.lock().unwrap();
                    if !st.open {
                        drop(st);
                        return Err((p, ServeError::ShuttingDown));
                    }
                    if st.live >= self.shared.max_pending {
                        drop(st);
                        return Err((
                            p,
                            ServeError::Overloaded { max_pending: self.shared.max_pending },
                        ));
                    }
                    st.live += 1;
                    st.pending.push_back(p);
                }
                cv.notify_one();
            }
            Dispatcher::Sharded { shards, live } => {
                // Reserve the live slot FIRST, then check closed/overload,
                // undoing on refusal. With SeqCst on both sides either a
                // draining worker sees live > 0 and keeps running, or this
                // thread sees the close and rejects — an admitted request
                // can never be stranded behind an exited worker (module
                // docs, backpressure section).
                let prev = live.fetch_add(1, Ordering::SeqCst);
                if shards.is_closed() {
                    live.fetch_sub(1, Ordering::SeqCst);
                    return Err((p, ServeError::ShuttingDown));
                }
                if prev >= self.shared.max_pending {
                    live.fetch_sub(1, Ordering::SeqCst);
                    return Err((
                        p,
                        ServeError::Overloaded { max_pending: self.shared.max_pending },
                    ));
                }
                self.shared.push_sharded(shards, p);
            }
        }
        Ok(())
    }

    /// The id string behind an adapter handle, for error naming (falls
    /// back to the slot index for ids from a foreign registry).
    fn adapter_name(&self, id: AdapterId) -> String {
        self.shared
            .registry
            .name_of(id)
            .unwrap_or_else(|| format!("#{}", id.index()))
    }

    fn admit(
        &self,
        layer: LayerId,
        adapter: Option<AdapterId>,
        x: Vec<f64>,
        tx: &CompletionSender<Response>,
    ) -> Result<Pending, ServeError> {
        let l = if layer.token() == self.shared.token {
            // Minted by THIS engine: in range by construction — the token
            // compare replaces the bounds check.
            &self.shared.model.layers[layer.index()]
        } else if layer.token() == 0 {
            // Legacy unbound handle: full validation.
            self.shared
                .model
                .get(layer)
                .ok_or_else(|| ServeError::UnknownLayer { layer: format!("#{}", layer.index()) })?
        } else {
            // Another engine's handle: its index names some OTHER model's
            // layer — refuse typed instead of serving whatever sits at
            // that index here.
            return Err(ServeError::BadRoute {
                detail: format!(
                    "layer handle #{} was minted by a different engine (identity token \
                     mismatch)",
                    layer.index()
                ),
            });
        };
        if x.len() != l.rows {
            return Err(ServeError::ShapeMismatch {
                layer: l.name.clone(),
                detail: format!(
                    "input length {} but the layer takes {} features",
                    x.len(),
                    l.rows
                ),
            });
        }
        let handle = match adapter {
            None => None,
            Some(id) => {
                let h = self.checkout(id)?;
                if h.pair(layer).is_none() {
                    return Err(ServeError::AdapterMismatch {
                        adapter: self.adapter_name(id),
                        layer: Some(l.name.clone()),
                    });
                }
                Some(h)
            }
        };
        let adapter_slot = adapter.map(|id| id.index() as u32);
        let mut trace = self.shared.telemetry.begin_trace(TraceKind::Single, adapter_slot);
        if let Some(t) = trace.as_deref_mut() {
            t.event(TraceStage::Admitted { layer: layer.index() as u32 });
        }
        Ok(Pending {
            layer,
            adapter: handle,
            adapter_slot,
            x,
            t_in: Instant::now(),
            trace,
            kind: HopKind::Single { tx: tx.clone() },
        })
    }

    /// Admission for model/session requests: the route arrives
    /// pre-validated (built by [`ServeEngine::route`] /
    /// [`PackedModel::route`]) and is re-checked against THIS model in
    /// O(route) integer compares, so a route from a smaller or
    /// unchainable foreign model is a typed [`ServeError::BadRoute`]
    /// (an in-range, chainable route from a different model addresses by
    /// index, like any handle — see the [`LayerId`] docs). The adapter is
    /// pinned once and must matter somewhere on the route; layers it
    /// carries no delta for run base-only — the LoRA-on-a-subset
    /// deployment shape.
    fn admit_traversal(
        &self,
        route: &Route,
        adapter: Option<AdapterId>,
        x: Vec<f64>,
        steps: usize,
        step: Option<StepFn>,
        tx: &CompletionSender<ModelResponse>,
    ) -> Result<Pending, ServeError> {
        if steps < 1 {
            return Err(ServeError::InvalidConfig {
                detail: "session must run at least one forward pass".to_string(),
            });
        }
        if route.token() == self.shared.token {
            // Built by `ServeEngine::route`: validated against THIS model
            // at construction — one integer compare replaces the O(hops)
            // re-walk on every submission.
        } else if route.token() == 0 {
            self.shared.model.validate_route(route.as_ids())?;
        } else {
            return Err(ServeError::BadRoute {
                detail: "route was built by a different engine (identity token mismatch)"
                    .to_string(),
            });
        }
        let head = route.as_ids()[0];
        let head_layer = &self.shared.model.layers[head.index()];
        if x.len() != head_layer.rows {
            return Err(ServeError::ShapeMismatch {
                layer: head_layer.name.clone(),
                detail: format!(
                    "route head input length {} but the layer takes {} features",
                    x.len(),
                    head_layer.rows
                ),
            });
        }
        let handle = match adapter {
            None => None,
            Some(id) => {
                let h = self.checkout(id)?;
                if !route.as_ids().iter().any(|&lid| h.pair(lid).is_some()) {
                    return Err(ServeError::AdapterMismatch {
                        adapter: self.adapter_name(id),
                        layer: None,
                    });
                }
                Some(h)
            }
        };
        let t_in = Instant::now();
        let adapter_slot = adapter.map(|id| id.index() as u32);
        let trace_kind = if steps > 1 { TraceKind::Session } else { TraceKind::Model };
        let mut trace = self.shared.telemetry.begin_trace(trace_kind, adapter_slot);
        if let Some(t) = trace.as_deref_mut() {
            t.event(TraceStage::Admitted { layer: head.index() as u32 });
        }
        let trace_id = trace.as_ref().map_or(0, |t| t.id());
        Ok(Pending {
            layer: head,
            adapter: handle,
            adapter_slot,
            x,
            t_in,
            trace,
            kind: HopKind::Traversal(Box::new(Traversal::new(
                route.clone(),
                steps,
                step,
                tx.clone(),
                t_in,
                trace_id,
            ))),
        })
    }

    fn checkout(&self, id: AdapterId) -> Result<AdapterHandle, ServeError> {
        if id.token() != self.shared.registry.token() {
            // A foreign registry's handle: its slot number would name
            // another tenant here, so refuse typed rather than guess.
            return Err(ServeError::AdapterMismatch {
                adapter: format!("#{}", id.index()),
                layer: None,
            });
        }
        self.shared
            .registry
            .checkout(id)
            .ok_or_else(|| ServeError::UnknownAdapter { adapter: self.adapter_name(id) })
    }

    /// Back-compat counter view, derived from the telemetry snapshot:
    /// the counts are exact (they were relaxed atomic increments), and
    /// the two time totals come from the hop-queue / batch-compute
    /// histogram nanosecond sums. An engine built with
    /// [`TelemetryOptions::disabled`] reads all-zero here.
    pub fn stats(&self) -> EngineStats {
        self.shared.telemetry.snapshot(&[]).engine_stats()
    }

    /// Merged telemetry snapshot: counters, latency histograms (with
    /// quantile estimates), per-layer and per-adapter attribution
    /// (labeled with the registry's live adapter names), and the
    /// recent/slow trace rings. Render with
    /// [`TelemetrySnapshot::render_prometheus`].
    pub fn telemetry(&self) -> TelemetrySnapshot {
        self.shared.telemetry.snapshot(&self.shared.registry.slot_names())
    }

    /// The engine's shared telemetry core — wire it into an
    /// [`crate::serve::artifact::ArtifactStore`] with
    /// `with_telemetry`, or scrape it from a metrics thread without
    /// holding the engine.
    pub fn telemetry_handle(&self) -> Arc<Telemetry> {
        Arc::clone(&self.shared.telemetry)
    }

    /// Stop admitting WITHOUT waiting: subsequent submits fail with
    /// [`ServeError::ShuttingDown`] while dispatch keeps draining every
    /// already-admitted request in the background. Call
    /// [`ServeEngine::shutdown`] (or drop the engine) to block until the
    /// drain completes.
    pub fn close(&self) {
        match &self.shared.dispatcher {
            Dispatcher::Global { state, cv, .. } => {
                {
                    let mut st = state.lock().unwrap();
                    st.open = false;
                }
                cv.notify_all();
            }
            Dispatcher::Sharded { shards, .. } => {
                // Sets the closed flag and broadcasts lock-then-notify to
                // every shard, so each parked worker re-evaluates its
                // closed+drained exit predicate.
                shards.close();
            }
        }
    }

    /// Stop admitting, drain every admitted request — including every
    /// remaining hop of in-flight model requests and sessions — join the
    /// dispatch threads, and return the final counters.
    pub fn shutdown(mut self) -> EngineStats {
        self.shutdown_impl(); // Drop runs it again; it is idempotent
        self.stats()
    }

    fn shutdown_impl(&mut self) {
        self.close();
        // Both cores drain until the last live hop slot is released (so
        // traversals finish their whole route) before their threads exit:
        // the global batcher additionally waits for its pool to go idle;
        // a shard worker's exit predicate (closed AND live == 0) already
        // implies every ticket has resolved, because batches run inline
        // and re-entries are queued before slots are released. So joining
        // here IS the full drain barrier.
        for h in self.threads.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for ServeEngine {
    fn drop(&mut self) {
        self.shutdown_impl();
    }
}

/// The [`Dispatch::Global`] dispatch thread: one FIFO, one holdback
/// counter, batches executed on the [`WorkerPool`].
fn batcher_loop(shared: Arc<Shared>) {
    let Dispatcher::Global { state, cv, pool } = &shared.dispatcher else {
        unreachable!("batcher_loop is spawned only under Dispatch::Global");
    };
    loop {
        let batch = {
            let mut st = state.lock().unwrap();
            // Hold back while every worker is busy: pending requests keep
            // piling up and coalesce into fuller batches (module docs).
            loop {
                if !st.pending.is_empty() && st.in_flight < shared.workers {
                    break;
                }
                // Exit only when nothing can re-enter: admissions closed
                // AND the last live hop slot released (an in-flight batch
                // may still push hops back into the FIFO, so an empty
                // queue alone is not drained).
                if !st.open && st.live == 0 {
                    drop(st);
                    pool.wait_idle(); // in-flight batches answer first
                    return;
                }
                st = cv.wait(st).unwrap();
            }
            st.in_flight += 1;
            take_batch(&mut st.pending, shared.max_batch)
        };
        let t_formed = Instant::now();
        let shared2 = Arc::clone(&shared);
        pool.submit(move || run_batch(&shared2, batch, t_formed));
    }
}

/// One [`Dispatch::Sharded`] worker: owner of shard `me`. Drains its own
/// shard first (same head-layer batch formation as the global FIFO), then
/// steals the OLDEST batchable group from the most-loaded other shard,
/// then parks with a short timeout — the timeout is what lets a worker
/// whose own shard is quiet notice other shards' backlogs (module docs,
/// steal-liveness). Batches execute INLINE on this thread; that is the
/// sharded core's holdback: at most `workers` batches can be in flight,
/// so under saturation the shards pile up and coalesce.
fn shard_worker_loop(shared: Arc<Shared>, me: usize) {
    let Dispatcher::Sharded { shards, live } = &shared.dispatcher else {
        unreachable!("shard_worker_loop is spawned only under Dispatch::Sharded");
    };
    // ~0.5 ms: long enough to cost nothing measurable when idle, short
    // enough that a steal opportunity is never stale by more than a
    // kernel-call timescale.
    const PARK: std::time::Duration = std::time::Duration::from_micros(500);
    loop {
        // (1) Own shard: the layer-affine fast path.
        let own = shards.pop_group(me, |q| {
            if q.is_empty() {
                Vec::new()
            } else {
                take_batch(q, shared.max_batch)
            }
        });
        if !own.is_empty() {
            run_batch(&shared, own, Instant::now());
            continue;
        }
        // (2) Steal: oldest batchable group from the deepest other shard.
        // The depth mirror may be stale, so an empty grab just falls
        // through to the park.
        if let Some(victim) = shards.most_loaded_other(me) {
            let stolen = shards.pop_group(victim, |q| {
                if q.is_empty() {
                    Vec::new()
                } else {
                    take_batch(q, shared.max_batch)
                }
            });
            if !stolen.is_empty() {
                shared.telemetry.incr(Counter::Steals);
                run_batch(&shared, stolen, Instant::now());
                continue;
            }
        }
        // (3) Park, or exit once closed AND fully drained. The predicate
        // order (closed first, then live) pairs with admission's
        // increment-then-check to rule out stranded requests.
        if !shards.park(me, PARK, || shards.is_closed() && live.load(Ordering::SeqCst) == 0) {
            return;
        }
    }
}

/// Pull the FIFO head plus every same-layer request behind it (≤ cap),
/// whatever adapters they carry, preserving the relative order of
/// everything left behind. Mixed-adapter batches are deliberate: the
/// grouped kernel shares the expensive base pass across ALL riders and
/// pays only per-group skinny products, so coalescing across adapters
/// still wins (the penalty is measured in BENCH_adapters.json). The scan
/// is bounded: it stops at the cap OR after examining `8·cap` entries, so
/// a deep multi-layer backlog costs O(cap) under the queue mutex, never
/// O(queue) — head-layer requests deeper than the scan window simply ride
/// a later batch.
fn take_batch(pending: &mut VecDeque<Pending>, cap: usize) -> Vec<Pending> {
    let layer = pending.front().expect("caller checked non-empty").layer;
    let scan_limit = cap.saturating_mul(8).max(1);
    let mut taken = Vec::new();
    let mut skipped = Vec::new(); // other-layer prefix entries, in order
    let mut scanned = 0usize;
    while let Some(p) = pending.pop_front() {
        scanned += 1;
        if p.layer == layer {
            taken.push(p);
            if taken.len() == cap {
                break; // untouched tail stays in place
            }
        } else {
            skipped.push(p);
        }
        if scanned == scan_limit {
            break;
        }
    }
    while let Some(p) = skipped.pop() {
        pending.push_front(p);
    }
    taken
}

/// Sort key making same-EFFECTIVE-slot riders adjacent at this layer:
/// rows the kernel will run base-only first (no adapter, or an adapter
/// with no delta for this layer — partial-coverage traversal hops), then
/// by the `LoraPair`'s address — exactly the identity `same_adapter`
/// groups on, so the sort can never split an achievable group (and two
/// versions of one id, a hot-swap caught mid-queue, can never share
/// one). Allocation- and hash-free: the per-layer adapter lookup is the
/// handle's O(1) slot table ([`AdapterHandle::pair`]), and this runs for
/// every rider of every micro-batch. Group ORDER is irrelevant (row
/// placement cannot change any response's numbers — the parity
/// contract), only adjacency matters.
fn adapter_sort_key(p: &Pending, layer: LayerId) -> (u8, usize) {
    match p.adapter.as_ref().and_then(|h| h.pair(layer)) {
        None => (0, 0),
        Some(pair) => (1, pair as *const LoraPair as usize),
    }
}

fn run_batch(shared: &Shared, mut batch: Vec<Pending>, t_formed: Instant) {
    let tel = &shared.telemetry;
    let layer_id = batch[0].layer;
    let layer = &shared.model.layers[layer_id.index()];
    let layer_name = layer.name.as_str();
    let bs = batch.len();
    // Lazy artifact verification: a zero-copy (mmap-v3) code section
    // checks its CRC on FIRST TOUCH, which is here — the moment a kernel
    // is about to read the words. A corrupt section fails this batch's
    // riders with the typed Artifact error naming the layer, instead of
    // serving garbage bits; the result is cached, so the layer pays one
    // CRC pass ever (clean or corrupt). Eagerly-loaded layers verified at
    // open time return Ok without rescanning. The pre-probe makes the
    // first-touch pass countable (two racing batches may both observe
    // "pending" and double-count — a diagnostic counter, not an
    // invariant, so the race is acceptable).
    let crc_was_pending = layer.crc_pending();
    if let Err(e) = layer.verify() {
        if crc_was_pending {
            tel.incr(Counter::CrcLazyVerifications);
            tel.incr(Counter::CrcFailures);
        }
        let finished = batch.len();
        for p in batch {
            let Pending { trace, kind, .. } = p;
            match kind {
                HopKind::Single { tx } => {
                    tel.incr(Counter::SinglesFailed);
                    let _ = tx.send(Err(e.clone()));
                }
                HopKind::Traversal(tr) => {
                    tel.incr(Counter::ModelsFailed);
                    tel.add(Counter::SessionForwards, tr.fail(e.clone()) as u64);
                }
            }
            if let Some(t) = trace {
                tel.finish_trace(t, false);
            }
        }
        complete_batch(shared, Vec::new(), finished);
        return;
    }
    if crc_was_pending {
        tel.incr(Counter::CrcLazyVerifications);
    }
    // Same-effective-slot requests adjacent ⇒ fewest adapter groups.
    // Stable, so arrival order survives within a group. Row placement
    // cannot change any response's numbers (grouped-kernel parity
    // contract).
    batch.sort_by_cached_key(|p| adapter_sort_key(p, layer_id));
    let mut xs = Matrix::zeros(bs, layer.rows);
    for (k, p) in batch.iter().enumerate() {
        xs.row_mut(k).copy_from_slice(&p.x);
    }
    // Per-row adapter slots for the grouped kernel. Single-layer riders
    // always resolve (admission checked coverage); a traversal hop may
    // land on a route layer its adapter carries no delta for — that row
    // runs base-only, by design.
    let slots: Vec<Option<&LoraPair>> =
        batch.iter().map(|p| p.adapter.as_ref().and_then(|h| h.pair(layer_id))).collect();
    let groups = count_groups(&slots);
    // Contain a kernel panic to this batch: every rider gets a typed
    // WorkerPanic naming the layer (not a bogus ShuttingDown), the worker
    // survives, and the in-flight slot is still released below.
    let t_exec = Instant::now();
    let kernel = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        layer.forward_batch_grouped(&xs, &slots)
    }));
    let compute_s = t_exec.elapsed().as_secs_f64();
    drop(slots);

    let rows_of = |id: LayerId| shared.model.layers[id.index()].rows;
    let mut reentry: Vec<Pending> = Vec::new();
    let mut finished = 0usize; // riders whose ticket resolved in this batch
    match &kernel {
        Ok(ys) => {
            // Batch-level telemetry: relaxed adds on this worker's shard —
            // the stats mutex the old EngineStats took per batch is gone.
            tel.add(Counter::Hops, bs as u64);
            tel.incr(Counter::Batches);
            tel.record_batch_max(bs);
            if groups > 1 {
                tel.incr(Counter::MixedBatches);
            }
            tel.observe(Metric::BatchCompute, compute_s);
            let compute_ns = (compute_s * 1e9) as u64;
            // The kernel ran once for all riders; a rider's fair share of
            // it is 1/bs — what the per-adapter compute attribution sums.
            let share_ns = compute_ns / bs as u64;
            let mut total_queue = 0.0;
            for (k, p) in batch.into_iter().enumerate() {
                let Pending { adapter, adapter_slot, t_in, mut trace, kind, .. } = p;
                let queue_s = t_formed.saturating_duration_since(t_in).as_secs_f64();
                total_queue += queue_s;
                tel.observe(Metric::HopQueue, queue_s);
                tel.observe(Metric::HopLatency, queue_s + compute_s);
                if let Some(slot) = adapter_slot {
                    tel.adapter_hop(slot, (queue_s * 1e9) as u64, share_ns);
                }
                if let Some(t) = trace.as_deref_mut() {
                    t.hop(layer_id.index() as u32, bs as u32, groups as u32, queue_s, compute_s);
                }
                match kind {
                    HopKind::Single { tx } => {
                        finished += 1;
                        tel.incr(Counter::SinglesOk);
                        let resp = Response {
                            y: ys.row(k).to_vec(),
                            queue_s,
                            compute_s,
                            batch_size: bs,
                            adapter_groups: groups,
                            trace_id: trace.as_ref().map_or(0, |t| t.id()),
                        };
                        let _ = tx.send(Ok(resp)); // requester may have given up; fine
                        if let Some(t) = trace {
                            tel.finish_trace(t, true);
                        }
                    }
                    HopKind::Traversal(tr) => {
                        let outcome = tr.absorb_hop(
                            ys.row(k).to_vec(),
                            queue_s,
                            compute_s,
                            bs,
                            groups,
                            &rows_of,
                        );
                        match outcome {
                            HopOutcome::Reenter { layer, x, traversal } => {
                                if let Some(t) = trace.as_deref_mut() {
                                    t.event(TraceStage::Enqueued {
                                        layer: layer.index() as u32,
                                    });
                                }
                                reentry.push(Pending {
                                    layer,
                                    adapter,
                                    adapter_slot,
                                    x,
                                    t_in: Instant::now(),
                                    trace,
                                    kind: HopKind::Traversal(traversal),
                                });
                            }
                            HopOutcome::Replied { ok, forwards } => {
                                finished += 1;
                                tel.add(Counter::SessionForwards, forwards as u64);
                                tel.incr(if ok {
                                    Counter::ModelsOk
                                } else {
                                    Counter::ModelsFailed
                                });
                                if let Some(t) = trace {
                                    tel.finish_trace(t, ok);
                                }
                            }
                        }
                    }
                }
            }
            tel.layer_batch(layer_id.index(), bs, (total_queue * 1e9) as u64, compute_ns);
        }
        Err(_) => {
            tel.incr(Counter::BatchPanics);
            for p in batch {
                finished += 1;
                let Pending { trace, kind, .. } = p;
                match kind {
                    HopKind::Single { tx } => {
                        tel.incr(Counter::SinglesFailed);
                        let _ = tx.send(Err(ServeError::WorkerPanic {
                            layer: layer_name.to_string(),
                            batch: bs,
                            hop: None,
                        }));
                    }
                    HopKind::Traversal(tr) => {
                        tel.incr(Counter::ModelsFailed);
                        let hop = tr.hops_done() + 1;
                        tel.add(
                            Counter::SessionForwards,
                            tr.fail(ServeError::WorkerPanic {
                                layer: layer_name.to_string(),
                                batch: bs,
                                hop: Some(hop),
                            }) as u64,
                        );
                    }
                }
                if let Some(t) = trace {
                    tel.finish_trace(t, false);
                }
            }
        }
    }
    complete_batch(shared, reentry, finished);
}

/// Finish one micro-batch against the dispatch core: re-enter continuing
/// traversals at their next layer and hand the finished riders' live
/// slots back. Re-entry bypasses the admission gate on purpose — these
/// hops were admitted once and must finish even while the engine is
/// draining (admissions closed).
fn complete_batch(shared: &Shared, reentry: Vec<Pending>, finished: usize) {
    match &shared.dispatcher {
        Dispatcher::Global { state, cv, .. } => {
            {
                // One lock: the re-entries and both counters move together.
                let mut st = state.lock().unwrap();
                st.pending.extend(reentry);
                st.in_flight -= 1;
                st.live -= finished;
            }
            cv.notify_all(); // wake the batcher: a worker slot / new hops
        }
        Dispatcher::Sharded { shards, live } => {
            // Re-entries are pushed BEFORE the finished slots are
            // released: `live` counts whole traversals, so live == 0 must
            // imply no Pending exists in any shard — that implication is
            // what makes the workers' closed+drained exit (and shutdown's
            // join-only barrier) correct.
            for p in reentry {
                shared.telemetry.incr(Counter::ShardReentries);
                shared.push_sharded(shards, p);
            }
            if finished > 0 {
                let prev = live.fetch_sub(finished, Ordering::SeqCst);
                if prev == finished && shards.is_closed() {
                    // Last slot released after close: wake every parked
                    // worker through the lost-wakeup-proof broadcast so
                    // the drain barrier completes promptly.
                    shards.wake_all();
                }
            }
        }
    }
}

/// Number of consecutive same-adapter runs in the (sorted) slot list —
/// the group count the kernel will execute. Uses the kernel's own
/// identity test (`packed::same_adapter`), so this count cannot drift
/// from the grouping `forward_batch_grouped` actually performs.
fn count_groups(slots: &[Option<&LoraPair>]) -> usize {
    let mut groups = 0usize;
    for (i, &s) in slots.iter().enumerate() {
        if i == 0 || !crate::serve::packed::same_adapter(slots[i - 1], s) {
            groups += 1;
        }
    }
    groups
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{quantize_rtn, QuantState};
    use crate::serve::packed::PackedLayer;
    use crate::util::prng::Rng;

    fn model(seed: u64) -> PackedModel {
        let mut rng = Rng::new(seed);
        let mut layers = Vec::new();
        for (name, m, n) in [("wq", 24usize, 10usize), ("wo", 18, 7)] {
            let w = Matrix::randn(m, n, 0.3, &mut rng);
            let q = QuantState::Int(quantize_rtn(&w, 4, 8));
            layers.push(PackedLayer::from_state(name, &q).unwrap());
        }
        PackedModel::new(layers)
    }

    fn adapter(id: &str, model: &PackedModel, r: usize, seed: u64) -> AdapterSet {
        let mut rng = Rng::new(seed);
        let mut set = AdapterSet::new(id);
        for l in &model.layers {
            let pair = LoraPair::new(
                Matrix::randn(l.rows, r, 0.1, &mut rng),
                Matrix::randn(l.cols, r, 0.1, &mut rng),
            );
            set.insert(&l.name, pair).unwrap();
        }
        set
    }

    #[test]
    fn builder_validates_and_rejects_bad_knobs() {
        let err = ServeEngine::builder(model(399)).workers(0).build().unwrap_err();
        assert!(matches!(err, ServeError::InvalidConfig { .. }), "{err:?}");
        assert!(format!("{err}").contains("workers"), "{err}");
        let err = ServeEngine::builder(model(399)).max_batch(0).build().unwrap_err();
        assert!(format!("{err}").contains("max_batch"), "{err}");
        let err = ServeEngine::builder(PackedModel::default()).build().unwrap_err();
        assert!(format!("{err}").contains("no layers"), "{err}");
        // Duplicate layer names are a build-time InvalidConfig, not a panic.
        let m = model(398);
        let dup = PackedModel::new(vec![m.layers[0].clone(), m.layers[0].clone()]);
        let err = ServeEngine::builder(dup).build().unwrap_err();
        assert!(format!("{err}").contains("duplicate layer name 'wq'"), "{err}");
        // The dispatch knob flows through the same validation: a bad knob
        // is refused identically under either core, and both cores build.
        let err = ServeEngine::builder(model(399))
            .dispatch(Dispatch::Global)
            .workers(0)
            .build()
            .unwrap_err();
        assert!(format!("{err}").contains("workers"), "{err}");
        assert_eq!(Dispatch::default(), Dispatch::Sharded);
        for d in [Dispatch::Sharded, Dispatch::Global] {
            ServeEngine::builder(model(399)).dispatch(d).build().unwrap().shutdown();
        }
    }

    #[test]
    fn responses_match_direct_forward_bit_for_bit() {
        let m = model(400);
        let sets = [adapter("t0", &m, 3, 410), adapter("t1", &m, 5, 411)];
        // Direct serial references: request i → layer i%2, adapter i%3
        // (index 2 = base only).
        let mut rng = Rng::new(401);
        let direct: Vec<Vec<f64>> = (0..12)
            .map(|i| {
                let l = &m.layers[i % 2];
                let x = rng.gauss_vec(l.rows);
                let pair = match i % 3 {
                    2 => None,
                    k => Some(sets[k].get(&l.name).unwrap()),
                };
                l.forward(&x, pair)
            })
            .collect();
        let engine = ServeEngine::builder(model(400)).workers(2).max_batch(4).build().unwrap();
        let mut tenant_ids = Vec::new();
        for s in sets {
            tenant_ids.push(engine.register_adapter(s).unwrap().id);
        }
        let layer_ids =
            [engine.layer("wq").unwrap(), engine.layer("wo").unwrap()];
        let mut rng = Rng::new(401); // same stream → same inputs
        let reqs: Vec<Request> = (0..12)
            .map(|i| {
                let lid = layer_ids[i % 2];
                let x = rng.gauss_vec(engine.model().get(lid).unwrap().rows);
                match i % 3 {
                    2 => Request::base(lid, x),
                    k => Request::with_adapter(lid, tenant_ids[k], x),
                }
            })
            .collect();
        let tickets = engine.submit_all(reqs);
        for (k, t) in tickets.into_iter().enumerate() {
            let r = t.wait().unwrap();
            assert_eq!(r.y.len(), direct[k].len());
            for (u, v) in r.y.iter().zip(&direct[k]) {
                assert_eq!(u.to_bits(), v.to_bits(), "request {k}");
            }
            assert!(r.batch_size >= 1 && r.batch_size <= 4);
            assert!(r.adapter_groups >= 1 && r.adapter_groups <= r.batch_size);
        }
        let stats = engine.shutdown();
        assert_eq!(stats.requests, 12);
        assert_eq!(stats.hops, 12, "single-layer requests are one hop each");
        assert!(stats.batches < 12, "burst must coalesce: {stats:?}");
        assert!(stats.max_batch_seen >= 2, "{stats:?}");
        assert!(stats.mixed_batches >= 1, "3 tenants over 2 layers must mix: {stats:?}");
    }

    #[test]
    fn invalid_requests_rejected_with_typed_errors() {
        let m = model(402);
        let wq_only = {
            let mut rng = Rng::new(412);
            let l = m.layer("wq").unwrap();
            let mut s = AdapterSet::new("partial");
            s.insert(
                "wq",
                LoraPair::new(
                    Matrix::randn(l.rows, 2, 0.1, &mut rng),
                    Matrix::randn(l.cols, 2, 0.1, &mut rng),
                ),
            )
            .unwrap();
            s
        };
        let engine = ServeEngine::builder(m).build().unwrap();
        let partial = engine.register_adapter(wq_only).unwrap().id;
        let (wq, wo) = (engine.layer("wq").unwrap(), engine.layer("wo").unwrap());
        // Unknown names fail at RESOLUTION, with the name echoed back.
        let err = engine.layer("nope").unwrap_err();
        assert!(matches!(&err, ServeError::UnknownLayer { layer } if layer == "nope"), "{err}");
        let err = engine.adapter("ghost").unwrap_err();
        assert!(
            matches!(&err, ServeError::UnknownAdapter { adapter } if adapter == "ghost"),
            "{err}"
        );
        // The name-resolving submit path reports the same typed errors.
        let err = engine.submit_named("nope", None, vec![0.0; 4]).wait().unwrap_err();
        assert!(matches!(err, ServeError::UnknownLayer { .. }), "{err:?}");
        let err = engine.submit(wq, None, vec![0.0; 3]).wait().unwrap_err();
        assert!(
            matches!(&err, ServeError::ShapeMismatch { layer, .. } if layer == "wq"),
            "{err:?}"
        );
        assert!(format!("{err}").contains("24 features"), "{err}");
        let err = engine.submit(wo, Some(partial), vec![0.0; 18]).wait().unwrap_err();
        assert!(
            matches!(
                &err,
                ServeError::AdapterMismatch { adapter, layer: Some(l) }
                    if adapter == "partial" && l == "wo"
            ),
            "{err:?}"
        );
        let stats = engine.shutdown();
        assert_eq!(stats.rejected, 3, "resolution failures never reach the queue");
        assert_eq!(stats.requests, 0);
    }

    #[test]
    fn misshapen_adapter_rejected_at_registration() {
        let m = model(403);
        let mut bad = AdapterSet::new("bad");
        bad.insert("wq", LoraPair::new(Matrix::zeros(24, 2), Matrix::zeros(9, 2))).unwrap();
        let engine = ServeEngine::builder(m).build().unwrap();
        let err = engine.register_adapter(bad).unwrap_err();
        assert!(matches!(err, ServeError::ShapeMismatch { .. }), "{err:?}");
        let msg = format!("{err}");
        assert!(msg.contains("adapter 'bad'"), "{msg}");
        assert!(msg.contains("does not fit base"), "{msg}");
        engine.shutdown();
    }

    #[test]
    fn shutdown_drains_queued_requests() {
        let engine =
            ServeEngine::builder(model(404)).workers(1).max_batch(8).build().unwrap();
        let wq = engine.layer("wq").unwrap();
        let mut rng = Rng::new(405);
        let tickets: Vec<Ticket> =
            (0..32).map(|_| engine.submit(wq, None, rng.gauss_vec(24))).collect();
        let stats = engine.shutdown(); // must answer everything first
        assert_eq!(stats.requests, 32);
        for t in tickets {
            assert!(t.wait().is_ok());
        }
    }

    #[test]
    fn global_dispatch_still_serves_and_drains() {
        // The reference core stays fully functional behind the knob: it
        // is the parity baseline sharded dispatch is judged against.
        let engine = ServeEngine::builder(model(404))
            .dispatch(Dispatch::Global)
            .workers(2)
            .max_batch(8)
            .build()
            .unwrap();
        let wq = engine.layer("wq").unwrap();
        let mut rng = Rng::new(415);
        let tickets: Vec<Ticket> =
            (0..32).map(|_| engine.submit(wq, None, rng.gauss_vec(24))).collect();
        for t in tickets {
            assert!(t.wait().is_ok());
        }
        // Steal/re-entry counters are sharded-dispatch instruments; the
        // global core must never tick them.
        let snap = engine.telemetry();
        assert_eq!(snap.counter(Counter::Steals), 0);
        assert_eq!(snap.max_shard_depth_seen, 0);
        let stats = engine.shutdown();
        assert_eq!(stats.requests, 32);
        assert_eq!(stats.rejected, 0);
    }

    #[test]
    fn close_rejects_new_submits_while_draining_admitted_ones() {
        let engine =
            ServeEngine::builder(model(408)).workers(1).max_batch(8).build().unwrap();
        let wq = engine.layer("wq").unwrap();
        let mut rng = Rng::new(409);
        let tickets: Vec<Ticket> =
            (0..16).map(|_| engine.submit(wq, None, rng.gauss_vec(24))).collect();
        engine.close();
        let err = engine.submit(wq, None, rng.gauss_vec(24)).wait().unwrap_err();
        assert!(matches!(err, ServeError::ShuttingDown), "{err:?}");
        // Already-admitted requests still complete.
        for t in tickets {
            assert!(t.wait().is_ok(), "admitted requests must survive close()");
        }
        let stats = engine.shutdown();
        assert_eq!(stats.requests, 16);
        assert_eq!(stats.rejected, 1);
    }

    #[test]
    fn unregister_waits_for_queued_requests_then_rejects_new_ones() {
        let m = model(406);
        let set = adapter("ten", &m, 2, 413);
        let engine = ServeEngine::builder(m).workers(1).max_batch(4).build().unwrap();
        let ten = engine.register_adapter(set).unwrap().id;
        let wq = engine.layer("wq").unwrap();
        let mut rng = Rng::new(407);
        let tickets: Vec<Ticket> =
            (0..16).map(|_| engine.submit(wq, Some(ten), rng.gauss_vec(24))).collect();
        engine.unregister_adapter("ten").unwrap(); // blocks until all 16 answered
        for t in tickets {
            assert!(t.wait().is_ok(), "queued requests must be served, not dropped");
        }
        // The stale AdapterId now resolves to UnknownAdapter — by NAME.
        let err = engine.submit(wq, Some(ten), rng.gauss_vec(24)).wait().unwrap_err();
        assert!(
            matches!(&err, ServeError::UnknownAdapter { adapter } if adapter == "ten"),
            "{err:?}"
        );
        engine.shutdown();
    }

    #[test]
    fn model_requests_rejected_with_typed_errors() {
        let engine = ServeEngine::builder(model(420)).build().unwrap();
        // wq outputs 10 wide; wo takes 18 — the chain is broken, and the
        // Route itself refuses to exist.
        let err = engine.route(&["wq", "wo"]).unwrap_err();
        assert!(matches!(err, ServeError::BadRoute { .. }), "{err:?}");
        assert!(format!("{err}").contains("route break"), "{err}");
        let err = engine.route(&["ghost"]).unwrap_err();
        assert!(matches!(&err, ServeError::UnknownLayer { layer } if layer == "ghost"), "{err}");
        let err = engine.route::<&str>(&[]).unwrap_err();
        assert!(format!("{err}").contains("route is empty"), "{err}");
        // A valid route with a misshapen input fails at submission.
        let route = engine.route(&["wq"]).unwrap();
        let err =
            engine.submit_model(ModelRequest::new(route, vec![0.0; 3])).wait().unwrap_err();
        assert!(matches!(err, ServeError::ShapeMismatch { .. }), "{err:?}");
        assert!(format!("{err}").contains("takes 24 features"), "{err}");
        let stats = engine.shutdown();
        assert_eq!(stats.rejected, 1, "route-construction failures never submit");
        assert_eq!(stats.model_requests, 0);
    }

    #[test]
    fn single_layer_model_request_matches_single_request() {
        // A one-hop route through the pipelined path must return the same
        // bits as the plain single-layer submit.
        let m = model(421);
        let engine = ServeEngine::builder(model(421)).workers(1).build().unwrap();
        let mut rng = Rng::new(422);
        let x = rng.gauss_vec(24);
        let direct = m.layers[0].forward(&x, None);
        let route = engine.route(&["wq"]).unwrap();
        let resp = engine.submit_model(ModelRequest::new(route, x)).wait().unwrap();
        for (u, v) in resp.y.iter().zip(&direct) {
            assert_eq!(u.to_bits(), v.to_bits());
        }
        assert_eq!(resp.forwards, 1);
        assert_eq!(resp.hops, 1);
        let stats = engine.shutdown();
        assert_eq!(stats.model_requests, 1);
        assert_eq!(stats.session_forwards, 1);
        assert_eq!(stats.hops, 1);
        assert_eq!(stats.requests, 0, "traversal hops are not single-layer requests");
    }
}

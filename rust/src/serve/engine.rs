//! The serving front-end: admits concurrent forward requests, coalesces
//! them into per-layer micro-batches, and executes the batches on a
//! persistent [`WorkerPool`].
//!
//! Shape of the pipeline:
//!
//! ```text
//!   submit() ──→ pending FIFO ──→ batcher thread ──→ WorkerPool job
//!                 (Mutex+Condvar)  (drains ≤ max_batch   (forward_batch,
//!                                   same-layer requests)  replies per req)
//! ```
//!
//! The batcher scans the FIFO head's layer and pulls every queued request
//! for that layer (up to `max_batch`), preserving the relative order of
//! the rest — arrival order stays fair across layers while the kernel's
//! row-reuse amortization (`PackedLayer::forward_batch`) is harvested
//! whenever requests pile up. Because the batched kernel is bit-identical
//! to serial calls (parity contract in `serve::packed`), coalescing is
//! purely a throughput decision: **batch composition can never change a
//! response's numbers**.
//!
//! Coalescing policy: no timers. The batcher dispatches immediately while
//! kernel workers are free (latency-first under light load), but keeps at
//! most `workers` micro-batches in flight — once the workers are all busy
//! it stops draining, so a saturating stream of single `submit()` calls
//! piles up in the FIFO and naturally coalesces into full batches
//! (throughput-first under saturation), and the pool's job queue stays
//! bounded by the worker count.
//!
//! Every [`Response`] reports its queue wait, its micro-batch's kernel
//! time and the batch size; [`EngineStats`] aggregates them for the bench
//! harness (`BENCH_serve.json`) and the demo.

use std::collections::VecDeque;
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::Instant;

use crate::linalg::Matrix;
use crate::serve::packed::PackedModel;
use crate::util::threadpool::WorkerPool;

#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    /// Kernel workers executing micro-batches.
    pub workers: usize,
    /// Coalescing cap: at most this many requests per micro-batch.
    pub max_batch: usize,
    /// Admission backpressure: requests arriving while this many are
    /// already pending are rejected with an "overloaded" error instead of
    /// growing the FIFO (and its buffered input vectors) without bound.
    pub max_pending: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self { workers: 2, max_batch: 16, max_pending: 4096 }
    }
}

/// One served forward result plus its latency breakdown.
#[derive(Clone, Debug)]
pub struct Response {
    pub y: Vec<f64>,
    /// Admission → micro-batch formation.
    pub queue_s: f64,
    /// Kernel time of the micro-batch this request rode in.
    pub compute_s: f64,
    /// Size of that micro-batch.
    pub batch_size: usize,
}

/// Aggregate engine counters (snapshot via [`ServeEngine::stats`]).
/// Invariant: every submitted request ends up in exactly one of
/// `requests` (served), `rejected` (invalid at admission), or `failed`
/// (rider of a panicked batch), so `requests + rejected + failed` equals
/// the number of submissions whose tickets have resolved.
#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    /// Requests served successfully.
    pub requests: usize,
    pub batches: usize,
    pub max_batch_seen: usize,
    /// Requests refused at admission (unknown layer, wrong width).
    pub rejected: usize,
    /// Micro-batches whose kernel panicked (the workers survive).
    pub batch_panics: usize,
    /// Riders of panicked batches; each got an `Err` naming the layer.
    pub failed: usize,
    pub total_queue_s: f64,
    pub total_compute_s: f64,
}

impl EngineStats {
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }

    pub fn mean_queue_s(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.total_queue_s / self.requests as f64
        }
    }
}

/// Handle to a submitted request; resolves to its [`Response`].
pub struct Ticket {
    rx: mpsc::Receiver<anyhow::Result<Response>>,
}

impl Ticket {
    /// Block until the engine answers (or report that it shut down first).
    pub fn wait(self) -> anyhow::Result<Response> {
        self.rx
            .recv()
            .map_err(|_| anyhow::anyhow!("serve engine dropped before answering"))?
    }
}

struct Pending {
    layer: usize,
    x: Vec<f64>,
    tx: mpsc::Sender<anyhow::Result<Response>>,
    t_in: Instant,
}

struct QueueState {
    pending: VecDeque<Pending>,
    open: bool,
    /// Micro-batches dispatched but not yet finished — the batcher holds
    /// back while this reaches the worker count (see the module docs'
    /// coalescing policy).
    in_flight: usize,
}

struct Shared {
    model: Arc<PackedModel>,
    /// Name → layer index, built once so admission is O(1) instead of a
    /// per-request linear scan over layer names.
    index: std::collections::HashMap<String, usize>,
    max_batch: usize,
    max_pending: usize,
    workers: usize,
    state: Mutex<QueueState>,
    cv: Condvar,
    stats: Mutex<EngineStats>,
}

/// The serving engine: batching front-end over a [`PackedModel`].
pub struct ServeEngine {
    shared: Arc<Shared>,
    batcher: Option<std::thread::JoinHandle<()>>,
}

impl ServeEngine {
    pub fn new(model: PackedModel, cfg: EngineConfig) -> ServeEngine {
        let mut index = std::collections::HashMap::with_capacity(model.layers.len());
        for (i, l) in model.layers.iter().enumerate() {
            // Unique names are a serving invariant (load_artifact enforces
            // it on untrusted bytes; this guards hand-built models) — with
            // duplicates, name-addressed requests would be ambiguous.
            let prev = index.insert(l.name.clone(), i);
            assert!(prev.is_none(), "ServeEngine: duplicate layer name '{}'", l.name);
        }
        let shared = Arc::new(Shared {
            model: Arc::new(model),
            index,
            max_batch: cfg.max_batch.max(1),
            max_pending: cfg.max_pending.max(1),
            workers: cfg.workers.max(1),
            state: Mutex::new(QueueState {
                pending: VecDeque::new(),
                open: true,
                in_flight: 0,
            }),
            cv: Condvar::new(),
            stats: Mutex::new(EngineStats::default()),
        });
        let pool = WorkerPool::new(cfg.workers);
        let batcher = {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || batcher_loop(shared, pool))
        };
        ServeEngine { shared, batcher: Some(batcher) }
    }

    /// Admit one forward request for layer `layer`. Invalid requests (no
    /// such layer, wrong input length) resolve immediately with an error —
    /// they never occupy queue space.
    pub fn submit(&self, layer: &str, x: Vec<f64>) -> Ticket {
        let (tx, rx) = mpsc::channel();
        match self.admit(layer, x, &tx) {
            Ok(p) => {
                let accepted = {
                    let mut st = self.shared.state.lock().unwrap();
                    if st.pending.len() < self.shared.max_pending {
                        st.pending.push_back(p);
                        true
                    } else {
                        false
                    }
                };
                if accepted {
                    self.shared.cv.notify_one();
                } else {
                    self.reject(&tx, self.overloaded());
                }
            }
            Err(e) => self.reject(&tx, e),
        }
        Ticket { rx }
    }

    /// Admit a burst of requests under ONE queue lock: the batcher cannot
    /// observe a partially-enqueued burst, so same-layer requests in the
    /// burst are guaranteed to be coalescible (up to `max_batch`).
    pub fn submit_all(&self, reqs: Vec<(String, Vec<f64>)>) -> Vec<Ticket> {
        let mut tickets = Vec::with_capacity(reqs.len());
        let mut admitted = Vec::with_capacity(reqs.len());
        for (layer, x) in reqs {
            let (tx, rx) = mpsc::channel();
            match self.admit(&layer, x, &tx) {
                Ok(p) => admitted.push(p),
                Err(e) => self.reject(&tx, e),
            }
            tickets.push(Ticket { rx });
        }
        let overflow = {
            let mut st = self.shared.state.lock().unwrap();
            let room = self.shared.max_pending.saturating_sub(st.pending.len());
            let overflow = if admitted.len() > room { admitted.split_off(room) } else { Vec::new() };
            st.pending.extend(admitted);
            overflow
        };
        for p in overflow {
            let tx = p.tx.clone();
            self.reject(&tx, self.overloaded());
        }
        self.shared.cv.notify_one();
        tickets
    }

    fn overloaded(&self) -> anyhow::Error {
        anyhow::anyhow!(
            "engine overloaded: pending queue at max_pending={}; retry later",
            self.shared.max_pending
        )
    }

    fn reject(&self, tx: &mpsc::Sender<anyhow::Result<Response>>, e: anyhow::Error) {
        self.shared.stats.lock().unwrap().rejected += 1;
        let _ = tx.send(Err(e));
    }

    fn admit(
        &self,
        layer: &str,
        x: Vec<f64>,
        tx: &mpsc::Sender<anyhow::Result<Response>>,
    ) -> anyhow::Result<Pending> {
        let idx = *self
            .shared
            .index
            .get(layer)
            .ok_or_else(|| anyhow::anyhow!("no such layer '{layer}' in the served model"))?;
        let rows = self.shared.model.layers[idx].rows;
        anyhow::ensure!(
            x.len() == rows,
            "layer '{layer}': input length {} but the layer takes {rows} features",
            x.len()
        );
        Ok(Pending { layer: idx, x, tx: tx.clone(), t_in: Instant::now() })
    }

    pub fn stats(&self) -> EngineStats {
        self.shared.stats.lock().unwrap().clone()
    }

    /// Stop admitting, drain every queued request, join the batcher and the
    /// kernel workers, and return the final counters.
    pub fn shutdown(mut self) -> EngineStats {
        self.shutdown_impl(); // Drop runs it again; it is idempotent
        self.stats()
    }

    fn shutdown_impl(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.open = false;
        }
        self.shared.cv.notify_all();
        if let Some(h) = self.batcher.take() {
            let _ = h.join(); // batcher drains the queue, then drops the pool (which drains its jobs)
        }
    }
}

impl Drop for ServeEngine {
    fn drop(&mut self) {
        self.shutdown_impl();
    }
}

fn batcher_loop(shared: Arc<Shared>, pool: WorkerPool) {
    loop {
        let batch = {
            let mut st = shared.state.lock().unwrap();
            // Hold back while every worker is busy: pending requests keep
            // piling up and coalesce into fuller batches (module docs).
            loop {
                if !st.pending.is_empty() && st.in_flight < shared.workers {
                    break;
                }
                if st.pending.is_empty() && !st.open {
                    drop(st);
                    pool.shutdown(); // drains in-flight kernel jobs first
                    return;
                }
                st = shared.cv.wait(st).unwrap();
            }
            st.in_flight += 1;
            take_batch(&mut st.pending, shared.max_batch)
        };
        let t_formed = Instant::now();
        let shared2 = Arc::clone(&shared);
        pool.submit(move || run_batch(&shared2, batch, t_formed));
    }
}

/// Pull the FIFO head plus every same-layer request behind it (≤ cap),
/// preserving the relative order of everything left behind. The scan is
/// bounded: it stops at the cap OR after examining `8·cap` entries, so a
/// deep multi-layer backlog (the saturation case the coalescing policy
/// exists for) costs O(cap) under the queue mutex, never O(queue) —
/// head-layer requests deeper than the scan window simply ride a later
/// batch.
fn take_batch(pending: &mut VecDeque<Pending>, cap: usize) -> Vec<Pending> {
    let layer = pending.front().expect("caller checked non-empty").layer;
    let scan_limit = cap.saturating_mul(8).max(1);
    let mut taken = Vec::new();
    let mut skipped = Vec::new(); // other-layer prefix entries, in order
    let mut scanned = 0usize;
    while let Some(p) = pending.pop_front() {
        scanned += 1;
        if p.layer == layer {
            taken.push(p);
            if taken.len() == cap {
                break; // untouched tail stays in place
            }
        } else {
            skipped.push(p);
        }
        if scanned == scan_limit {
            break;
        }
    }
    while let Some(p) = skipped.pop() {
        pending.push_front(p);
    }
    taken
}

fn run_batch(shared: &Shared, batch: Vec<Pending>, t_formed: Instant) {
    let layer = &shared.model.layers[batch[0].layer];
    let bs = batch.len();
    let mut xs = Matrix::zeros(bs, layer.rows);
    for (k, p) in batch.iter().enumerate() {
        xs.row_mut(k).copy_from_slice(&p.x);
    }
    // Contain a kernel panic to this batch: every rider gets an Err naming
    // it (not a bogus "engine dropped"), the worker survives, and the
    // in-flight slot is still released below.
    let t_exec = Instant::now();
    let kernel =
        std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| layer.forward_batch(&xs)));
    let compute_s = t_exec.elapsed().as_secs_f64();

    let mut total_queue = 0.0;
    match &kernel {
        Ok(ys) => {
            for (k, p) in batch.into_iter().enumerate() {
                let queue_s = t_formed.saturating_duration_since(p.t_in).as_secs_f64();
                total_queue += queue_s;
                let resp =
                    Response { y: ys.row(k).to_vec(), queue_s, compute_s, batch_size: bs };
                let _ = p.tx.send(Ok(resp)); // requester may have given up; fine
            }
        }
        Err(_) => {
            for p in batch {
                let _ = p.tx.send(Err(anyhow::anyhow!(
                    "layer '{}': serving batch of {bs} panicked in the kernel",
                    layer.name
                )));
            }
        }
    }
    {
        let mut stats = shared.stats.lock().unwrap();
        match &kernel {
            Ok(_) => {
                stats.requests += bs;
                stats.batches += 1;
                stats.max_batch_seen = stats.max_batch_seen.max(bs);
                stats.total_queue_s += total_queue;
                stats.total_compute_s += compute_s;
            }
            Err(_) => {
                stats.batch_panics += 1;
                stats.failed += bs;
            }
        }
    }
    let mut st = shared.state.lock().unwrap();
    st.in_flight -= 1;
    drop(st);
    shared.cv.notify_all(); // wake the batcher: a worker slot is free again
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{quantize_rtn, QuantState};
    use crate::serve::packed::PackedLayer;
    use crate::util::prng::Rng;

    fn model(seed: u64) -> PackedModel {
        let mut rng = Rng::new(seed);
        let mut layers = Vec::new();
        for (name, m, n) in [("wq", 24usize, 10usize), ("wo", 18, 7)] {
            let w = Matrix::randn(m, n, 0.3, &mut rng);
            let q = QuantState::Int(quantize_rtn(&w, 4, 8));
            let a = Matrix::randn(m, 3, 0.1, &mut rng);
            let b = Matrix::randn(n, 3, 0.1, &mut rng);
            layers.push(PackedLayer::from_state(name, &q, &a, &b).unwrap());
        }
        PackedModel::new(layers)
    }

    #[test]
    fn responses_match_direct_forward_bit_for_bit() {
        let m = model(400);
        let direct: Vec<Vec<f64>> = {
            let mut rng = Rng::new(401);
            (0..10)
                .map(|i| {
                    let l = &m.layers[i % 2];
                    l.forward(&rng.gauss_vec(l.rows))
                })
                .collect()
        };
        let engine = ServeEngine::new(model(400), EngineConfig { workers: 2, max_batch: 4, ..EngineConfig::default() });
        let mut rng = Rng::new(401); // same stream → same inputs
        let reqs: Vec<(String, Vec<f64>)> = (0..10)
            .map(|i| {
                let l = &engine.shared.model.layers[i % 2];
                (l.name.clone(), rng.gauss_vec(l.rows))
            })
            .collect();
        let tickets = engine.submit_all(reqs);
        for (k, t) in tickets.into_iter().enumerate() {
            let r = t.wait().unwrap();
            assert_eq!(r.y.len(), direct[k].len());
            for (u, v) in r.y.iter().zip(&direct[k]) {
                assert_eq!(u.to_bits(), v.to_bits(), "request {k}");
            }
            assert!(r.batch_size >= 1 && r.batch_size <= 4);
        }
        let stats = engine.shutdown();
        assert_eq!(stats.requests, 10);
        assert!(stats.batches < 10, "burst must coalesce: {stats:?}");
        assert!(stats.max_batch_seen >= 2, "{stats:?}");
    }

    #[test]
    fn invalid_requests_rejected_with_actionable_errors() {
        let engine = ServeEngine::new(model(402), EngineConfig::default());
        let msg = format!("{}", engine.submit("nope", vec![0.0; 4]).wait().unwrap_err());
        assert!(msg.contains("no such layer 'nope'"), "{msg}");
        let msg = format!("{}", engine.submit("wq", vec![0.0; 3]).wait().unwrap_err());
        assert!(msg.contains("24 features"), "{msg}");
        let stats = engine.shutdown();
        assert_eq!(stats.rejected, 2);
        assert_eq!(stats.requests, 0);
    }

    #[test]
    fn shutdown_drains_queued_requests() {
        let engine = ServeEngine::new(model(403), EngineConfig { workers: 1, max_batch: 8, ..EngineConfig::default() });
        let mut rng = Rng::new(404);
        let tickets: Vec<Ticket> =
            (0..32).map(|_| engine.submit("wq", rng.gauss_vec(24))).collect();
        let stats = engine.shutdown(); // must answer everything first
        assert_eq!(stats.requests, 32);
        for t in tickets {
            assert!(t.wait().is_ok());
        }
    }
}

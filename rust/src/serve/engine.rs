//! The serving front-end: admits concurrent forward requests (each naming
//! a layer and, optionally, an adapter), coalesces them into per-layer
//! micro-batches, and executes the batches on a persistent [`WorkerPool`].
//!
//! Shape of the pipeline:
//!
//! ```text
//!   submit() ──→ pending FIFO ──→ batcher thread ──→ WorkerPool job
//!                 (Mutex+Condvar)  (drains ≤ max_batch   (grouped batch
//!                                   same-layer requests)  kernel, replies
//!                                                         per request)
//! ```
//!
//! The batcher scans the FIFO head's layer and pulls every queued request
//! for that layer (up to `max_batch`), preserving the relative order of
//! the rest — arrival order stays fair across layers while the kernel's
//! row-reuse amortization (`PackedLayer::forward_batch_grouped`) is
//! harvested whenever requests pile up. **Adapter multiplexing**: each
//! request resolves its adapter to a pinned [`AdapterHandle`] at admission
//! (one version for its whole lifetime — a hot-swap can never mix old and
//! new weights in one response); the batch executor orders the micro-batch
//! so same-version requests are adjacent and runs the shared base pass
//! once, with one LoRA skinny product per adapter group. Because the
//! grouped kernel is bit-identical to serial single-adapter calls (parity
//! contract in `serve::packed`), coalescing — same-adapter or mixed — is
//! purely a throughput decision: **batch composition can never change a
//! response's numbers**.
//!
//! Coalescing policy: no timers. The batcher dispatches immediately while
//! kernel workers are free (latency-first under light load), but keeps at
//! most `workers` micro-batches in flight — once the workers are all busy
//! it stops draining, so a saturating stream of single `submit()` calls
//! piles up in the FIFO and naturally coalesces into full batches
//! (throughput-first under saturation), and the pool's job queue stays
//! bounded by the worker count.
//!
//! Every [`Response`] reports its queue wait, its micro-batch's kernel
//! time, the batch size and the adapter group count; [`EngineStats`]
//! aggregates them for the bench harness (`BENCH_serve.json` /
//! `BENCH_adapters.json`) and the demo.

use std::collections::VecDeque;
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::Instant;

use crate::linalg::Matrix;
use crate::lowrank::LoraPair;
use crate::serve::adapters::{AdapterHandle, AdapterRegistry, AdapterSet, RegisterOutcome};
use crate::serve::packed::PackedModel;
use crate::util::threadpool::WorkerPool;

#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    /// Kernel workers executing micro-batches.
    pub workers: usize,
    /// Coalescing cap: at most this many requests per micro-batch.
    pub max_batch: usize,
    /// Admission backpressure: requests arriving while this many are
    /// already pending are rejected with an "overloaded" error instead of
    /// growing the FIFO (and its buffered input vectors) without bound.
    pub max_pending: usize,
    /// Byte budget for the adapter registry's LRU cache (pinned adapters
    /// are exempt — see `AdapterRegistry::new`).
    pub adapter_budget_bytes: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self { workers: 2, max_batch: 16, max_pending: 4096, adapter_budget_bytes: usize::MAX }
    }
}

/// One forward request: which layer, which adapter (None = base only), and
/// the input activation.
#[derive(Clone, Debug)]
pub struct Request {
    pub layer: String,
    pub adapter: Option<String>,
    pub x: Vec<f64>,
}

impl Request {
    /// Base-only request (no adapter delta).
    pub fn base(layer: &str, x: Vec<f64>) -> Request {
        Request { layer: layer.to_string(), adapter: None, x }
    }

    /// Request routed through the named adapter.
    pub fn with_adapter(layer: &str, adapter: &str, x: Vec<f64>) -> Request {
        Request { layer: layer.to_string(), adapter: Some(adapter.to_string()), x }
    }
}

/// One served forward result plus its latency breakdown.
#[derive(Clone, Debug)]
pub struct Response {
    pub y: Vec<f64>,
    /// Admission → micro-batch formation.
    pub queue_s: f64,
    /// Kernel time of the micro-batch this request rode in.
    pub compute_s: f64,
    /// Size of that micro-batch.
    pub batch_size: usize,
    /// Distinct adapter groups (incl. the base-only group) in that batch —
    /// 1 means the batch was adapter-uniform.
    pub adapter_groups: usize,
}

/// Aggregate engine counters (snapshot via [`ServeEngine::stats`]).
/// Invariant: every submitted request ends up in exactly one of
/// `requests` (served), `rejected` (invalid at admission), or `failed`
/// (rider of a panicked batch), so `requests + rejected + failed` equals
/// the number of submissions whose tickets have resolved.
#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    /// Requests served successfully.
    pub requests: usize,
    pub batches: usize,
    pub max_batch_seen: usize,
    /// Micro-batches that mixed more than one adapter group (served via
    /// the grouped kernel's per-adapter skinny products).
    pub mixed_batches: usize,
    /// Requests refused at admission (unknown layer, wrong width, unknown
    /// adapter, adapter without the layer).
    pub rejected: usize,
    /// Micro-batches whose kernel panicked (the workers survive).
    pub batch_panics: usize,
    /// Riders of panicked batches; each got an `Err` naming the layer.
    pub failed: usize,
    pub total_queue_s: f64,
    pub total_compute_s: f64,
}

impl EngineStats {
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }

    pub fn mean_queue_s(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.total_queue_s / self.requests as f64
        }
    }
}

/// Handle to a submitted request; resolves to its [`Response`].
pub struct Ticket {
    rx: mpsc::Receiver<anyhow::Result<Response>>,
}

impl Ticket {
    /// Block until the engine answers (or report that it shut down first).
    pub fn wait(self) -> anyhow::Result<Response> {
        self.rx
            .recv()
            .map_err(|_| anyhow::anyhow!("serve engine dropped before answering"))?
    }
}

struct Pending {
    layer: usize,
    /// Pinned at admission; the pin lives until the response is sent, so
    /// eviction/unregister can never pull the weights out from under a
    /// queued or in-flight request.
    adapter: Option<AdapterHandle>,
    x: Vec<f64>,
    tx: mpsc::Sender<anyhow::Result<Response>>,
    t_in: Instant,
}

struct QueueState {
    pending: VecDeque<Pending>,
    open: bool,
    /// Micro-batches dispatched but not yet finished — the batcher holds
    /// back while this reaches the worker count (see the module docs'
    /// coalescing policy).
    in_flight: usize,
}

struct Shared {
    model: Arc<PackedModel>,
    /// Name → layer index, built once so admission is O(1) instead of a
    /// per-request linear scan over layer names.
    index: std::collections::HashMap<String, usize>,
    registry: Arc<AdapterRegistry>,
    max_batch: usize,
    max_pending: usize,
    workers: usize,
    state: Mutex<QueueState>,
    cv: Condvar,
    stats: Mutex<EngineStats>,
    pool: Arc<WorkerPool>,
}

/// The serving engine: adapter-multiplexed batching front-end over ONE
/// packed base [`PackedModel`] and many registered [`AdapterSet`]s.
pub struct ServeEngine {
    shared: Arc<Shared>,
    batcher: Option<std::thread::JoinHandle<()>>,
}

impl ServeEngine {
    pub fn new(model: PackedModel, cfg: EngineConfig) -> ServeEngine {
        let mut index = std::collections::HashMap::with_capacity(model.layers.len());
        for (i, l) in model.layers.iter().enumerate() {
            // Unique names are a serving invariant (the artifact loaders
            // enforce it on untrusted bytes; this guards hand-built models)
            // — with duplicates, name-addressed requests would be ambiguous.
            let prev = index.insert(l.name.clone(), i);
            assert!(prev.is_none(), "ServeEngine: duplicate layer name '{}'", l.name);
        }
        let shared = Arc::new(Shared {
            model: Arc::new(model),
            index,
            registry: Arc::new(AdapterRegistry::new(cfg.adapter_budget_bytes)),
            max_batch: cfg.max_batch.max(1),
            max_pending: cfg.max_pending.max(1),
            workers: cfg.workers.max(1),
            state: Mutex::new(QueueState {
                pending: VecDeque::new(),
                open: true,
                in_flight: 0,
            }),
            cv: Condvar::new(),
            stats: Mutex::new(EngineStats::default()),
            pool: Arc::new(WorkerPool::new(cfg.workers)),
        });
        let batcher = {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || batcher_loop(shared))
        };
        ServeEngine { shared, batcher: Some(batcher) }
    }

    /// Validate `set` against the served model's shapes and register it
    /// (hot-swapping any same-id predecessor; see the registry docs).
    pub fn register_adapter(&self, set: AdapterSet) -> anyhow::Result<RegisterOutcome> {
        set.check_against(&self.shared.model)?;
        self.shared.registry.register(set)
    }

    /// Remove the adapter and DRAIN it: blocks until every request pinned
    /// to any version of it (queued or in-flight, including versions
    /// superseded by hot-swaps) has been answered. The pin drain alone is
    /// the full barrier: a kernel job's weight borrows are dropped BEFORE
    /// its riders' pins are released (`run_batch` drops the slot table,
    /// sends the responses, then drops the handles), so once the last pin
    /// is gone no job can still be touching the weights — and unrelated
    /// tenants' traffic never delays the retirement (a global pool
    /// quiescence wait here would starve under sustained load). New
    /// submissions naming the id are rejected from the moment this is
    /// called.
    pub fn unregister_adapter(&self, id: &str) -> anyhow::Result<()> {
        self.shared.registry.unregister(id)
    }

    /// The adapter registry (checkout/stats access for diagnostics and
    /// tests; registration should go through [`ServeEngine::register_adapter`]
    /// so shapes are validated against the served model).
    pub fn registry(&self) -> &AdapterRegistry {
        &self.shared.registry
    }

    /// Admit one forward request. Invalid requests (no such layer, wrong
    /// input length, unknown adapter) resolve immediately with an error —
    /// they never occupy queue space.
    pub fn submit(&self, layer: &str, adapter: Option<&str>, x: Vec<f64>) -> Ticket {
        let (tx, rx) = mpsc::channel();
        match self.admit(layer, adapter, x, &tx) {
            Ok(p) => {
                let accepted = {
                    let mut st = self.shared.state.lock().unwrap();
                    if st.pending.len() < self.shared.max_pending {
                        st.pending.push_back(p);
                        true
                    } else {
                        false
                    }
                };
                if accepted {
                    self.shared.cv.notify_one();
                } else {
                    self.reject(&tx, self.overloaded());
                }
            }
            Err(e) => self.reject(&tx, e),
        }
        Ticket { rx }
    }

    /// Admit a burst of requests under ONE queue lock: the batcher cannot
    /// observe a partially-enqueued burst, so same-layer requests in the
    /// burst are guaranteed to be coalescible (up to `max_batch`).
    pub fn submit_all(&self, reqs: Vec<Request>) -> Vec<Ticket> {
        let mut tickets = Vec::with_capacity(reqs.len());
        let mut admitted = Vec::with_capacity(reqs.len());
        for req in reqs {
            let (tx, rx) = mpsc::channel();
            match self.admit(&req.layer, req.adapter.as_deref(), req.x, &tx) {
                Ok(p) => admitted.push(p),
                Err(e) => self.reject(&tx, e),
            }
            tickets.push(Ticket { rx });
        }
        let overflow = {
            let mut st = self.shared.state.lock().unwrap();
            let room = self.shared.max_pending.saturating_sub(st.pending.len());
            let overflow =
                if admitted.len() > room { admitted.split_off(room) } else { Vec::new() };
            st.pending.extend(admitted);
            overflow
        };
        for p in overflow {
            let tx = p.tx.clone();
            self.reject(&tx, self.overloaded());
        }
        self.shared.cv.notify_one();
        tickets
    }

    fn overloaded(&self) -> anyhow::Error {
        anyhow::anyhow!(
            "engine overloaded: pending queue at max_pending={}; retry later",
            self.shared.max_pending
        )
    }

    fn reject(&self, tx: &mpsc::Sender<anyhow::Result<Response>>, e: anyhow::Error) {
        self.shared.stats.lock().unwrap().rejected += 1;
        let _ = tx.send(Err(e));
    }

    fn admit(
        &self,
        layer: &str,
        adapter: Option<&str>,
        x: Vec<f64>,
        tx: &mpsc::Sender<anyhow::Result<Response>>,
    ) -> anyhow::Result<Pending> {
        let idx = *self
            .shared
            .index
            .get(layer)
            .ok_or_else(|| anyhow::anyhow!("no such layer '{layer}' in the served model"))?;
        let rows = self.shared.model.layers[idx].rows;
        anyhow::ensure!(
            x.len() == rows,
            "layer '{layer}': input length {} but the layer takes {rows} features",
            x.len()
        );
        let handle = match adapter {
            None => None,
            Some(id) => {
                let h = self.shared.registry.checkout(id).ok_or_else(|| {
                    anyhow::anyhow!(
                        "adapter '{id}' is not registered (never registered, evicted, \
                         or unregistered)"
                    )
                })?;
                anyhow::ensure!(
                    h.set().get(layer).is_some(),
                    "adapter '{id}' carries no delta for layer '{layer}'"
                );
                Some(h)
            }
        };
        Ok(Pending { layer: idx, adapter: handle, x, tx: tx.clone(), t_in: Instant::now() })
    }

    pub fn stats(&self) -> EngineStats {
        self.shared.stats.lock().unwrap().clone()
    }

    /// Stop admitting, drain every queued request, join the batcher and
    /// quiesce the kernel workers, and return the final counters.
    pub fn shutdown(mut self) -> EngineStats {
        self.shutdown_impl(); // Drop runs it again; it is idempotent
        self.stats()
    }

    fn shutdown_impl(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.open = false;
        }
        self.shared.cv.notify_all();
        if let Some(h) = self.batcher.take() {
            // The batcher drains the queue and waits for the pool to go
            // idle, so every ticket has resolved when join returns; the
            // workers themselves are joined when the last Shared drops.
            let _ = h.join();
        }
    }
}

impl Drop for ServeEngine {
    fn drop(&mut self) {
        self.shutdown_impl();
    }
}

fn batcher_loop(shared: Arc<Shared>) {
    loop {
        let batch = {
            let mut st = shared.state.lock().unwrap();
            // Hold back while every worker is busy: pending requests keep
            // piling up and coalesce into fuller batches (module docs).
            loop {
                if !st.pending.is_empty() && st.in_flight < shared.workers {
                    break;
                }
                if st.pending.is_empty() && !st.open {
                    drop(st);
                    shared.pool.wait_idle(); // in-flight batches answer first
                    return;
                }
                st = shared.cv.wait(st).unwrap();
            }
            st.in_flight += 1;
            take_batch(&mut st.pending, shared.max_batch)
        };
        let t_formed = Instant::now();
        let shared2 = Arc::clone(&shared);
        shared.pool.submit(move || run_batch(&shared2, batch, t_formed));
    }
}

/// Pull the FIFO head plus every same-layer request behind it (≤ cap),
/// whatever adapters they carry, preserving the relative order of
/// everything left behind. Mixed-adapter batches are deliberate: the
/// grouped kernel shares the expensive base pass across ALL riders and
/// pays only per-group skinny products, so coalescing across adapters
/// still wins (the penalty is measured in BENCH_adapters.json). The scan
/// is bounded: it stops at the cap OR after examining `8·cap` entries, so
/// a deep multi-layer backlog costs O(cap) under the queue mutex, never
/// O(queue) — head-layer requests deeper than the scan window simply ride
/// a later batch.
fn take_batch(pending: &mut VecDeque<Pending>, cap: usize) -> Vec<Pending> {
    let layer = pending.front().expect("caller checked non-empty").layer;
    let scan_limit = cap.saturating_mul(8).max(1);
    let mut taken = Vec::new();
    let mut skipped = Vec::new(); // other-layer prefix entries, in order
    let mut scanned = 0usize;
    while let Some(p) = pending.pop_front() {
        scanned += 1;
        if p.layer == layer {
            taken.push(p);
            if taken.len() == cap {
                break; // untouched tail stays in place
            }
        } else {
            skipped.push(p);
        }
        if scanned == scan_limit {
            break;
        }
    }
    while let Some(p) = skipped.pop() {
        pending.push_front(p);
    }
    taken
}

/// Sort key making same-adapter-version requests adjacent: base-only
/// first, then by adapter id, then by version token (two versions of one
/// id — a hot-swap caught mid-queue — must NOT share a group).
fn adapter_sort_key(p: &Pending) -> (u8, String, usize) {
    match &p.adapter {
        None => (0, String::new(), 0),
        Some(h) => (1, h.set().id().to_string(), h.version_token()),
    }
}

fn run_batch(shared: &Shared, mut batch: Vec<Pending>, t_formed: Instant) {
    let layer = &shared.model.layers[batch[0].layer];
    let layer_name = layer.name.as_str();
    let bs = batch.len();
    // Same-version requests adjacent ⇒ fewest adapter groups. Stable, so
    // arrival order survives within a group. Row placement cannot change
    // any response's numbers (grouped-kernel parity contract).
    batch.sort_by_cached_key(adapter_sort_key);
    let mut xs = Matrix::zeros(bs, layer.rows);
    for (k, p) in batch.iter().enumerate() {
        xs.row_mut(k).copy_from_slice(&p.x);
    }
    // Per-row adapter slots for the grouped kernel. The pair lookups are
    // infallible: admission checked the adapter carries this layer.
    let slots: Vec<Option<&LoraPair>> = batch
        .iter()
        .map(|p| {
            p.adapter
                .as_ref()
                .map(|h| h.set().get(layer_name).expect("validated at admission"))
        })
        .collect();
    let groups = count_groups(&slots);
    // Contain a kernel panic to this batch: every rider gets an Err naming
    // it (not a bogus "engine dropped"), the worker survives, and the
    // in-flight slot is still released below.
    let t_exec = Instant::now();
    let kernel = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        layer.forward_batch_grouped(&xs, &slots)
    }));
    let compute_s = t_exec.elapsed().as_secs_f64();
    drop(slots);

    let mut total_queue = 0.0;
    match &kernel {
        Ok(ys) => {
            for (k, p) in batch.into_iter().enumerate() {
                let queue_s = t_formed.saturating_duration_since(p.t_in).as_secs_f64();
                total_queue += queue_s;
                let resp = Response {
                    y: ys.row(k).to_vec(),
                    queue_s,
                    compute_s,
                    batch_size: bs,
                    adapter_groups: groups,
                };
                let _ = p.tx.send(Ok(resp)); // requester may have given up; fine
            }
        }
        Err(_) => {
            for p in batch {
                let _ = p.tx.send(Err(anyhow::anyhow!(
                    "layer '{layer_name}': serving batch of {bs} panicked in the kernel"
                )));
            }
        }
    }
    {
        let mut stats = shared.stats.lock().unwrap();
        match &kernel {
            Ok(_) => {
                stats.requests += bs;
                stats.batches += 1;
                stats.max_batch_seen = stats.max_batch_seen.max(bs);
                if groups > 1 {
                    stats.mixed_batches += 1;
                }
                stats.total_queue_s += total_queue;
                stats.total_compute_s += compute_s;
            }
            Err(_) => {
                stats.batch_panics += 1;
                stats.failed += bs;
            }
        }
    }
    let mut st = shared.state.lock().unwrap();
    st.in_flight -= 1;
    drop(st);
    shared.cv.notify_all(); // wake the batcher: a worker slot is free again
}

/// Number of consecutive same-adapter runs in the (sorted) slot list —
/// the group count the kernel will execute. Uses the kernel's own
/// identity test (`packed::same_adapter`), so this count cannot drift
/// from the grouping `forward_batch_grouped` actually performs.
fn count_groups(slots: &[Option<&LoraPair>]) -> usize {
    let mut groups = 0usize;
    for (i, &s) in slots.iter().enumerate() {
        if i == 0 || !crate::serve::packed::same_adapter(slots[i - 1], s) {
            groups += 1;
        }
    }
    groups
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{quantize_rtn, QuantState};
    use crate::serve::packed::PackedLayer;
    use crate::util::prng::Rng;

    fn model(seed: u64) -> PackedModel {
        let mut rng = Rng::new(seed);
        let mut layers = Vec::new();
        for (name, m, n) in [("wq", 24usize, 10usize), ("wo", 18, 7)] {
            let w = Matrix::randn(m, n, 0.3, &mut rng);
            let q = QuantState::Int(quantize_rtn(&w, 4, 8));
            layers.push(PackedLayer::from_state(name, &q).unwrap());
        }
        PackedModel::new(layers)
    }

    fn adapter(id: &str, model: &PackedModel, r: usize, seed: u64) -> AdapterSet {
        let mut rng = Rng::new(seed);
        let mut set = AdapterSet::new(id);
        for l in &model.layers {
            let pair = LoraPair::new(
                Matrix::randn(l.rows, r, 0.1, &mut rng),
                Matrix::randn(l.cols, r, 0.1, &mut rng),
            );
            set.insert(&l.name, pair).unwrap();
        }
        set
    }

    #[test]
    fn responses_match_direct_forward_bit_for_bit() {
        let m = model(400);
        let sets = [adapter("t0", &m, 3, 410), adapter("t1", &m, 5, 411)];
        // Direct serial references: request i → layer i%2, adapter i%3
        // (index 2 = base only).
        let mut rng = Rng::new(401);
        let direct: Vec<Vec<f64>> = (0..12)
            .map(|i| {
                let l = &m.layers[i % 2];
                let x = rng.gauss_vec(l.rows);
                let pair = match i % 3 {
                    2 => None,
                    k => Some(sets[k].get(&l.name).unwrap()),
                };
                l.forward(&x, pair)
            })
            .collect();
        let engine = ServeEngine::new(
            model(400),
            EngineConfig { workers: 2, max_batch: 4, ..EngineConfig::default() },
        );
        for s in sets {
            engine.register_adapter(s).unwrap();
        }
        let mut rng = Rng::new(401); // same stream → same inputs
        let reqs: Vec<Request> = (0..12)
            .map(|i| {
                let l = &engine.shared.model.layers[i % 2];
                let x = rng.gauss_vec(l.rows);
                match i % 3 {
                    2 => Request::base(&l.name, x),
                    k => Request::with_adapter(&l.name, &format!("t{k}"), x),
                }
            })
            .collect();
        let tickets = engine.submit_all(reqs);
        for (k, t) in tickets.into_iter().enumerate() {
            let r = t.wait().unwrap();
            assert_eq!(r.y.len(), direct[k].len());
            for (u, v) in r.y.iter().zip(&direct[k]) {
                assert_eq!(u.to_bits(), v.to_bits(), "request {k}");
            }
            assert!(r.batch_size >= 1 && r.batch_size <= 4);
            assert!(r.adapter_groups >= 1 && r.adapter_groups <= r.batch_size);
        }
        let stats = engine.shutdown();
        assert_eq!(stats.requests, 12);
        assert!(stats.batches < 12, "burst must coalesce: {stats:?}");
        assert!(stats.max_batch_seen >= 2, "{stats:?}");
        assert!(stats.mixed_batches >= 1, "3 tenants over 2 layers must mix: {stats:?}");
    }

    #[test]
    fn invalid_requests_rejected_with_actionable_errors() {
        let m = model(402);
        let wq_only = {
            let mut rng = Rng::new(412);
            let l = m.layer("wq").unwrap();
            let mut s = AdapterSet::new("partial");
            s.insert(
                "wq",
                LoraPair::new(
                    Matrix::randn(l.rows, 2, 0.1, &mut rng),
                    Matrix::randn(l.cols, 2, 0.1, &mut rng),
                ),
            )
            .unwrap();
            s
        };
        let engine = ServeEngine::new(m, EngineConfig::default());
        engine.register_adapter(wq_only).unwrap();
        let msg = format!("{}", engine.submit("nope", None, vec![0.0; 4]).wait().unwrap_err());
        assert!(msg.contains("no such layer 'nope'"), "{msg}");
        let msg = format!("{}", engine.submit("wq", None, vec![0.0; 3]).wait().unwrap_err());
        assert!(msg.contains("24 features"), "{msg}");
        let msg = format!(
            "{}",
            engine.submit("wq", Some("ghost"), vec![0.0; 24]).wait().unwrap_err()
        );
        assert!(msg.contains("adapter 'ghost' is not registered"), "{msg}");
        let msg = format!(
            "{}",
            engine.submit("wo", Some("partial"), vec![0.0; 18]).wait().unwrap_err()
        );
        assert!(msg.contains("no delta for layer 'wo'"), "{msg}");
        let stats = engine.shutdown();
        assert_eq!(stats.rejected, 4);
        assert_eq!(stats.requests, 0);
    }

    #[test]
    fn misshapen_adapter_rejected_at_registration() {
        let m = model(403);
        let mut bad = AdapterSet::new("bad");
        bad.insert("wq", LoraPair::new(Matrix::zeros(24, 2), Matrix::zeros(9, 2))).unwrap();
        let engine = ServeEngine::new(m, EngineConfig::default());
        let msg = format!("{}", engine.register_adapter(bad).unwrap_err());
        assert!(msg.contains("adapter 'bad'"), "{msg}");
        assert!(msg.contains("does not fit base"), "{msg}");
        engine.shutdown();
    }

    #[test]
    fn shutdown_drains_queued_requests() {
        let engine = ServeEngine::new(
            model(404),
            EngineConfig { workers: 1, max_batch: 8, ..EngineConfig::default() },
        );
        let mut rng = Rng::new(405);
        let tickets: Vec<Ticket> =
            (0..32).map(|_| engine.submit("wq", None, rng.gauss_vec(24))).collect();
        let stats = engine.shutdown(); // must answer everything first
        assert_eq!(stats.requests, 32);
        for t in tickets {
            assert!(t.wait().is_ok());
        }
    }

    #[test]
    fn unregister_waits_for_queued_requests_then_rejects_new_ones() {
        let m = model(406);
        let set = adapter("ten", &m, 2, 413);
        let engine = ServeEngine::new(
            m,
            EngineConfig { workers: 1, max_batch: 4, ..EngineConfig::default() },
        );
        engine.register_adapter(set).unwrap();
        let mut rng = Rng::new(407);
        let tickets: Vec<Ticket> =
            (0..16).map(|_| engine.submit("wq", Some("ten"), rng.gauss_vec(24))).collect();
        engine.unregister_adapter("ten").unwrap(); // blocks until all 16 answered
        for t in tickets {
            assert!(t.wait().is_ok(), "queued requests must be served, not dropped");
        }
        let msg = format!(
            "{}",
            engine.submit("wq", Some("ten"), rng.gauss_vec(24)).wait().unwrap_err()
        );
        assert!(msg.contains("not registered"), "{msg}");
        engine.shutdown();
    }
}

//! Multi-tenant adapter state: named LoRA adapter sets over one packed
//! base, and the registry that hot-swaps them under load.
//!
//! CLoQ's output is exactly a frozen quantized base plus a per-task LoRA
//! pair, so a production server loads the packed base ONCE and routes each
//! request to one of many cheap adapters. The two types here are the
//! tenant half of that split:
//!
//! * [`AdapterSet`] — one tenant's adapters: a named collection of
//!   per-layer [`LoraPair`]s, validated against a [`PackedModel`]'s shapes
//!   before serving.
//! * [`AdapterRegistry`] — the live set of tenants: `register` /
//!   `unregister` / hot-swap under load, LRU eviction under a byte budget,
//!   and RAII [`AdapterHandle`] checkouts that pin an adapter while any
//!   request references it.
//!
//! **Consistency contract** (locked down by
//! `rust/tests/lifecycle_adapters.rs`): a request resolves its adapter to
//! an [`AdapterHandle`] exactly once, at admission, and computes its whole
//! response through that handle — so a hot-swap (re-`register` under the
//! same id) can NEVER mix old and new weights inside one response; it only
//! changes which version requests admitted *after* the swap see. Eviction
//! and `unregister` respect pins across ALL versions of an id (a
//! hot-swap's still-pinned predecessors stay tracked as superseded): an
//! adapter with queued or in-flight requests is never evicted, and
//! `unregister` blocks until the last handle on any of its versions drops
//! (the per-adapter drain).

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::lowrank::LoraPair;
use crate::serve::packed::PackedModel;

/// One tenant's adapters: per-layer LoRA pairs keyed by layer name.
#[derive(Clone, Debug)]
pub struct AdapterSet {
    id: String,
    layers: Vec<(String, LoraPair)>,
    index: HashMap<String, usize>,
}

impl AdapterSet {
    pub fn new(id: &str) -> AdapterSet {
        AdapterSet { id: id.to_string(), layers: Vec::new(), index: HashMap::new() }
    }

    /// Build from `(layer name, pair)` entries; duplicate layer names are
    /// rejected (requests address adapters by layer name).
    pub fn from_pairs(id: &str, pairs: Vec<(String, LoraPair)>) -> anyhow::Result<AdapterSet> {
        let mut set = AdapterSet::new(id);
        for (layer, pair) in pairs {
            set.insert(&layer, pair)?;
        }
        Ok(set)
    }

    pub fn insert(&mut self, layer: &str, pair: LoraPair) -> anyhow::Result<()> {
        anyhow::ensure!(
            !self.index.contains_key(layer),
            "adapter '{}': duplicate entry for layer '{layer}'",
            self.id
        );
        self.index.insert(layer.to_string(), self.layers.len());
        self.layers.push((layer.to_string(), pair));
        Ok(())
    }

    pub fn id(&self) -> &str {
        &self.id
    }

    pub fn get(&self, layer: &str) -> Option<&LoraPair> {
        self.index.get(layer).map(|&i| &self.layers[i].1)
    }

    pub fn len(&self) -> usize {
        self.layers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.layers.is_empty()
    }

    /// `(layer name, pair)` entries in insertion order (the artifact writer
    /// iterates this, so save → load → save is byte-stable).
    pub fn entries(&self) -> impl Iterator<Item = (&str, &LoraPair)> {
        self.layers.iter().map(|(n, p)| (n.as_str(), p))
    }

    /// Adapter payload bytes (both factors of every pair, f64) — the unit
    /// of the registry's eviction budget.
    pub fn bytes(&self) -> usize {
        self.layers.iter().map(|(_, p)| p.bytes()).sum()
    }

    /// Validate every entry against `model`: the layer must exist and the
    /// pair must fit its base shape. Run at registration so admission and
    /// the kernel never see a misshapen adapter.
    pub fn check_against(&self, model: &PackedModel) -> anyhow::Result<()> {
        for (name, pair) in self.entries() {
            let layer = model.layer(name).ok_or_else(|| {
                anyhow::anyhow!(
                    "adapter '{}': no layer '{name}' in the served model",
                    self.id
                )
            })?;
            layer.check_adapter(pair).map_err(|e| anyhow::anyhow!("adapter '{}': {e}", self.id))?;
        }
        Ok(())
    }
}

/// A registered adapter version plus its live pin count. One `ActiveAdapter`
/// per `register` call: hot-swapping an id creates a NEW `ActiveAdapter`,
/// so pins on the old version keep the old weights alive and coherent.
pub struct ActiveAdapter {
    set: AdapterSet,
    in_use: AtomicUsize,
}

impl ActiveAdapter {
    pub fn set(&self) -> &AdapterSet {
        &self.set
    }

    /// Live checkout count (queued + in-flight requests holding a handle).
    pub fn pins(&self) -> usize {
        self.in_use.load(Ordering::Acquire)
    }
}

/// RAII pin on one adapter version. Held by a request from admission until
/// its response is sent; while any handle exists the version cannot be
/// evicted and `unregister` of its id blocks (the drain).
pub struct AdapterHandle {
    active: Arc<ActiveAdapter>,
    shared: Arc<RegShared>,
}

impl AdapterHandle {
    pub fn set(&self) -> &AdapterSet {
        &self.active.set
    }

    /// Same underlying version? (Identity, not value, comparison — the
    /// engine keys batch groups on this.)
    pub fn same_version(&self, other: &AdapterHandle) -> bool {
        Arc::ptr_eq(&self.active, &other.active)
    }

    /// Opaque version identity token (the engine's batch sorter uses it to
    /// make same-version requests adjacent; two handles return the same
    /// token iff [`AdapterHandle::same_version`] holds).
    pub fn version_token(&self) -> usize {
        Arc::as_ptr(&self.active) as usize
    }
}

impl Clone for AdapterHandle {
    fn clone(&self) -> AdapterHandle {
        self.active.in_use.fetch_add(1, Ordering::AcqRel);
        AdapterHandle { active: Arc::clone(&self.active), shared: Arc::clone(&self.shared) }
    }
}

impl Drop for AdapterHandle {
    fn drop(&mut self) {
        if self.active.in_use.fetch_sub(1, Ordering::AcqRel) == 1 {
            // Last pin gone: take the registry lock before notifying so a
            // drain waiter cannot check the count and then miss the wakeup.
            let _guard = self.shared.state.lock().unwrap();
            self.shared.drained.notify_all();
        }
    }
}

struct Entry {
    active: Arc<ActiveAdapter>,
    /// Superseded versions of this id still pinned by queued/in-flight
    /// requests (hot-swap under load). Tracked so `unregister` drains the
    /// OLD weights too, and eviction never drops a version some request
    /// still holds. Pruned lazily on every hot-swap, checkout and stats
    /// call, so drained old weights do not linger past the id's next
    /// touch.
    superseded: Vec<Arc<ActiveAdapter>>,
    bytes: usize,
    /// Registry clock at the last checkout/registration — the LRU key.
    last_used: u64,
}

impl Entry {
    fn any_pinned(&self) -> bool {
        self.active.pins() > 0 || self.superseded.iter().any(|a| a.pins() > 0)
    }
}

struct RegState {
    entries: HashMap<String, Entry>,
    clock: u64,
    bytes_total: usize,
    evictions: usize,
}

struct RegShared {
    state: Mutex<RegState>,
    drained: Condvar,
}

/// What `register` did besides inserting: whether it hot-swapped an
/// existing id, and which adapters the byte budget pushed out.
#[derive(Clone, Debug, Default)]
pub struct RegisterOutcome {
    pub replaced: bool,
    pub evicted: Vec<String>,
}

/// Point-in-time registry counters.
#[derive(Clone, Debug, Default)]
pub struct RegistryStats {
    pub adapters: usize,
    pub bytes: usize,
    pub evictions: usize,
}

/// The live adapter set: id → current version, LRU-evicted under
/// `budget_bytes`. All operations are safe under concurrent serving load;
/// see the module docs for the hot-swap and drain contracts.
pub struct AdapterRegistry {
    shared: Arc<RegShared>,
    budget_bytes: usize,
}

impl AdapterRegistry {
    /// `budget_bytes` caps the total adapter payload held (pinned adapters
    /// are exempt from eviction, so a fully-pinned registry may transiently
    /// exceed the budget — by design, since evicting an adapter with queued
    /// requests would fail those requests for a cache policy's sake).
    pub fn new(budget_bytes: usize) -> AdapterRegistry {
        AdapterRegistry {
            shared: Arc::new(RegShared {
                state: Mutex::new(RegState {
                    entries: HashMap::new(),
                    clock: 0,
                    bytes_total: 0,
                    evictions: 0,
                }),
                drained: Condvar::new(),
            }),
            budget_bytes: budget_bytes.max(1),
        }
    }

    /// Insert (or hot-swap) `set` under its id, then evict least-recently
    /// used UNPINNED adapters until the byte budget holds. A set larger
    /// than the whole budget is refused outright. Hot-swap does not wait
    /// for the old version's pins: in-flight requests finish on the old
    /// weights, new admissions see the new ones.
    pub fn register(&self, set: AdapterSet) -> anyhow::Result<RegisterOutcome> {
        let bytes = set.bytes();
        anyhow::ensure!(
            bytes <= self.budget_bytes,
            "adapter '{}': {bytes} bytes exceed the whole registry budget of {} bytes",
            set.id(),
            self.budget_bytes
        );
        let id = set.id().to_string();
        let mut st = self.shared.state.lock().unwrap();
        let mut outcome = RegisterOutcome::default();
        // Hot-swap: still-pinned predecessor versions move onto the new
        // entry so unregister/eviction keep seeing their pins; fully
        // drained ones drop here.
        let mut superseded = Vec::new();
        if let Some(old) = st.entries.remove(&id) {
            st.bytes_total -= old.bytes;
            outcome.replaced = true;
            superseded.extend(old.superseded.into_iter().filter(|a| a.pins() > 0));
            if old.active.pins() > 0 {
                superseded.push(old.active);
            }
        }
        st.clock += 1;
        let stamp = st.clock;
        st.bytes_total += bytes;
        st.entries.insert(
            id.clone(),
            Entry {
                active: Arc::new(ActiveAdapter { set, in_use: AtomicUsize::new(0) }),
                superseded,
                bytes,
                last_used: stamp,
            },
        );
        while st.bytes_total > self.budget_bytes {
            // LRU among candidates with NO pinned version (current or
            // superseded), never the id just registered.
            let victim = st
                .entries
                .iter()
                .filter(|(k, e)| **k != id && !e.any_pinned())
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone());
            match victim {
                Some(v) => {
                    let e = st.entries.remove(&v).unwrap();
                    st.bytes_total -= e.bytes;
                    st.evictions += 1;
                    outcome.evicted.push(v);
                }
                None => break, // everything else is pinned: tolerate over-budget
            }
        }
        Ok(outcome)
    }

    /// Pin and return the current version of `id` (bumping its recency), or
    /// `None` if it is not registered (never was, evicted, or unregistered).
    pub fn checkout(&self, id: &str) -> Option<AdapterHandle> {
        let mut st = self.shared.state.lock().unwrap();
        st.clock += 1;
        let stamp = st.clock;
        let entry = st.entries.get_mut(id)?;
        entry.superseded.retain(|a| a.pins() > 0); // free drained old weights
        entry.last_used = stamp;
        entry.active.in_use.fetch_add(1, Ordering::AcqRel);
        Some(AdapterHandle { active: Arc::clone(&entry.active), shared: Arc::clone(&self.shared) })
    }

    /// Remove `id` and BLOCK until every outstanding handle on EVERY
    /// version of it — the current one and any still-pinned hot-swap
    /// predecessors — drops: the per-adapter drain. On return no request,
    /// queued or in-flight, references any of the id's weights. New
    /// checkouts of the id fail the moment this is called (the entry is
    /// gone before the wait), so admission cannot re-pin a draining
    /// adapter.
    pub fn unregister(&self, id: &str) -> anyhow::Result<()> {
        let mut st = self.shared.state.lock().unwrap();
        let entry = st
            .entries
            .remove(id)
            .ok_or_else(|| anyhow::anyhow!("no adapter '{id}' registered"))?;
        st.bytes_total -= entry.bytes;
        while entry.any_pinned() {
            st = self.shared.drained.wait(st).unwrap();
        }
        Ok(())
    }

    pub fn contains(&self, id: &str) -> bool {
        self.shared.state.lock().unwrap().entries.contains_key(id)
    }

    /// Registered ids, alphabetical (diagnostics / demo output).
    pub fn ids(&self) -> Vec<String> {
        let st = self.shared.state.lock().unwrap();
        let mut ids: Vec<String> = st.entries.keys().cloned().collect();
        ids.sort();
        ids
    }

    pub fn stats(&self) -> RegistryStats {
        let mut st = self.shared.state.lock().unwrap();
        for e in st.entries.values_mut() {
            e.superseded.retain(|a| a.pins() > 0); // free drained old weights
        }
        RegistryStats {
            adapters: st.entries.len(),
            bytes: st.bytes_total,
            evictions: st.evictions,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::util::prng::Rng;

    fn pair(m: usize, n: usize, r: usize, seed: u64) -> LoraPair {
        let mut rng = Rng::new(seed);
        LoraPair::new(Matrix::randn(m, r, 0.1, &mut rng), Matrix::randn(n, r, 0.1, &mut rng))
    }

    fn set(id: &str, seed: u64) -> AdapterSet {
        AdapterSet::from_pairs(id, vec![("lin".to_string(), pair(8, 4, 2, seed))]).unwrap()
    }

    #[test]
    fn set_lookup_and_bytes() {
        let s = set("t0", 1);
        assert_eq!(s.id(), "t0");
        assert_eq!(s.len(), 1);
        assert!(s.get("lin").is_some());
        assert!(s.get("nope").is_none());
        assert_eq!(s.bytes(), (8 * 2 + 4 * 2) * 8);
    }

    #[test]
    fn duplicate_layer_rejected() {
        let mut s = set("t0", 2);
        let err = s.insert("lin", pair(8, 4, 2, 3)).unwrap_err();
        assert!(format!("{err}").contains("duplicate"), "{err}");
    }

    #[test]
    fn register_checkout_unregister() {
        let reg = AdapterRegistry::new(usize::MAX);
        reg.register(set("a", 4)).unwrap();
        assert!(reg.contains("a"));
        {
            let h = reg.checkout("a").unwrap();
            assert_eq!(h.set().id(), "a");
        }
        reg.unregister("a").unwrap();
        assert!(!reg.contains("a"));
        assert!(reg.checkout("a").is_none());
        let err = reg.unregister("a").unwrap_err();
        assert!(format!("{err}").contains("no adapter 'a'"), "{err}");
    }

    #[test]
    fn lru_eviction_respects_recency() {
        let one = set("x", 5).bytes();
        let reg = AdapterRegistry::new(2 * one);
        reg.register(set("a", 5)).unwrap();
        reg.register(set("b", 6)).unwrap();
        drop(reg.checkout("a").unwrap()); // touch a: b is now LRU
        let out = reg.register(set("c", 7)).unwrap();
        assert_eq!(out.evicted, vec!["b".to_string()]);
        assert!(reg.contains("a") && reg.contains("c"));
        assert_eq!(reg.stats().evictions, 1);
    }

    #[test]
    fn pinned_adapter_never_evicted() {
        let one = set("x", 8).bytes();
        let reg = AdapterRegistry::new(2 * one);
        reg.register(set("a", 8)).unwrap();
        let _pin = reg.checkout("a").unwrap();
        reg.register(set("b", 9)).unwrap();
        drop(reg.checkout("b").unwrap()); // a is LRU but pinned
        let out = reg.register(set("c", 10)).unwrap();
        assert_eq!(out.evicted, vec!["b".to_string()], "pinned 'a' must be skipped");
        assert!(reg.contains("a"));
        // With everything pinned, over-budget is tolerated rather than
        // failing live requests.
        let _pin_c = reg.checkout("c").unwrap();
        let out = reg.register(set("d", 11)).unwrap();
        assert!(out.evicted.is_empty());
        assert!(reg.stats().bytes > 2 * one);
    }

    #[test]
    fn oversized_set_refused() {
        let reg = AdapterRegistry::new(8);
        let err = reg.register(set("big", 12)).unwrap_err();
        assert!(format!("{err}").contains("exceed the whole registry budget"), "{err}");
    }

    #[test]
    fn hot_swap_is_versioned() {
        let reg = AdapterRegistry::new(usize::MAX);
        reg.register(set("a", 13)).unwrap();
        let old = reg.checkout("a").unwrap();
        let out = reg.register(set("a", 14)).unwrap();
        assert!(out.replaced);
        let new = reg.checkout("a").unwrap();
        assert!(!old.same_version(&new), "swap must mint a new version");
        // The old version's weights are still reachable through the pin.
        let (oa, na) = (old.set().get("lin").unwrap(), new.set().get("lin").unwrap());
        assert_ne!(oa.a.data, na.a.data, "distinct seeds ⇒ distinct weights");
    }

    #[test]
    fn unregister_drains_superseded_versions_too() {
        // A request pinned to the OLD version across a hot-swap must still
        // block unregister: the drain contract covers every version of the
        // id, not just the current one.
        let reg = Arc::new(AdapterRegistry::new(usize::MAX));
        reg.register(set("a", 20)).unwrap();
        let old_pin = reg.checkout("a").unwrap();
        reg.register(set("a", 21)).unwrap(); // hot-swap; old version still pinned
        let done = Arc::new(AtomicUsize::new(0));
        let waiter = {
            let (reg, done) = (Arc::clone(&reg), Arc::clone(&done));
            std::thread::spawn(move || {
                reg.unregister("a").unwrap();
                done.store(1, Ordering::SeqCst);
            })
        };
        std::thread::sleep(std::time::Duration::from_millis(30));
        assert_eq!(
            done.load(Ordering::SeqCst),
            0,
            "drain must block on the superseded version's pin"
        );
        drop(old_pin);
        waiter.join().unwrap();
        assert_eq!(done.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn eviction_skips_entries_with_pinned_superseded_versions() {
        let one = set("x", 22).bytes();
        let reg = AdapterRegistry::new(2 * one);
        reg.register(set("a", 22)).unwrap();
        let old_pin = reg.checkout("a").unwrap();
        reg.register(set("a", 23)).unwrap(); // swap: current unpinned, old pinned
        reg.register(set("b", 24)).unwrap();
        drop(reg.checkout("b").unwrap()); // a is LRU but its old version is pinned
        let out = reg.register(set("c", 25)).unwrap();
        assert_eq!(out.evicted, vec!["b".to_string()], "superseded pin must protect 'a'");
        assert!(reg.contains("a"));
        drop(old_pin);
    }

    #[test]
    fn unregister_drains_outstanding_handles() {
        let reg = Arc::new(AdapterRegistry::new(usize::MAX));
        reg.register(set("a", 15)).unwrap();
        let h = reg.checkout("a").unwrap();
        let h2 = h.clone();
        drop(h);
        let done = Arc::new(AtomicUsize::new(0));
        let waiter = {
            let (reg, done) = (Arc::clone(&reg), Arc::clone(&done));
            std::thread::spawn(move || {
                reg.unregister("a").unwrap();
                done.store(1, Ordering::SeqCst);
            })
        };
        std::thread::sleep(std::time::Duration::from_millis(30));
        assert_eq!(done.load(Ordering::SeqCst), 0, "drain must block while a handle lives");
        assert!(reg.checkout("a").is_none(), "draining adapter must refuse new pins");
        drop(h2);
        waiter.join().unwrap();
        assert_eq!(done.load(Ordering::SeqCst), 1);
    }
}

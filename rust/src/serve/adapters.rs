//! Multi-tenant adapter state: named LoRA adapter sets over one packed
//! base, and the interned, model-aware registry that hot-swaps them under
//! load.
//!
//! CLoQ's output is exactly a frozen quantized base plus a per-task LoRA
//! pair, so a production server loads the packed base ONCE and routes each
//! request to one of many cheap adapters. The types here are the tenant
//! half of that split:
//!
//! * [`AdapterSet`] — one tenant's adapters: a named collection of
//!   per-layer [`LoraPair`]s, validated against a [`PackedModel`]'s shapes
//!   at registration.
//! * [`AdapterId`] — an interned tenant handle: registering a set interns
//!   its string id into a stable slot; requests submit by `AdapterId`
//!   (`Copy`, one integer) so the admission hot path neither hashes nor
//!   clones id strings. A slot survives hot-swaps AND unregister/
//!   re-register of the same id, so resolved ids never dangle — checkout
//!   of a currently-unregistered slot just returns `None`.
//! * [`AdapterRegistry`] — the live tenant set, bound to the served
//!   [`PackedModel`]: `register` / `unregister` / hot-swap under load, LRU
//!   eviction under a byte budget, and RAII [`AdapterHandle`] checkouts
//!   that pin an adapter while any request references it. Because the
//!   registry knows its model, registration always shape-checks and also
//!   resolves each set into a per-model-layer slot table — the kernel's
//!   per-rider adapter lookup ([`AdapterHandle::pair`]) is one array
//!   index, not a per-hop string hash.
//!
//! **Consistency contract** (locked down by
//! `rust/tests/lifecycle_adapters.rs`): a request resolves its adapter to
//! an [`AdapterHandle`] exactly once, at admission, and computes its whole
//! response through that handle — so a hot-swap (re-`register` under the
//! same id) can NEVER mix old and new weights inside one response; it only
//! changes which version requests admitted *after* the swap see. Eviction
//! and `unregister` respect pins across ALL versions of an id (a
//! hot-swap's still-pinned predecessors stay tracked as superseded): an
//! adapter with queued or in-flight requests is never evicted, and
//! `unregister` blocks until the last handle on any of its versions drops
//! (the per-adapter drain).

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::lowrank::LoraPair;
use crate::serve::error::ServeError;
use crate::serve::packed::{LayerId, PackedModel};

/// An interned adapter handle: the stable slot index its string id was
/// assigned at first registration, plus the slot's **generation** at
/// minting time. `Copy`, hash-free to compare, and stable across
/// hot-swaps — resolve once ([`AdapterRegistry::resolve`] /
/// `ServeEngine::adapter`), then submit by id.
///
/// Ids carry their minting registry's **identity token**: checkout (and
/// engine admission) compares it first, so an id from a DIFFERENT
/// registry fails typed instead of silently addressing whichever tenant
/// sits in that slot of this one.
///
/// The **generation word** scopes the id to one registration incarnation:
/// unregistering (or evicting) an id and registering the same string
/// again bumps the slot's generation, so a handle minted before the
/// removal fails checkout typed ([`ServeError::UnknownAdapter`] at the
/// engine) instead of silently addressing the new tenant's weights.
/// Hot-swaps do NOT bump the generation — a swap is a new version of the
/// SAME incarnation, and held ids keep resolving to it.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct AdapterId {
    slot: u32,
    gen: u32,
    token: u64,
}

impl AdapterId {
    /// The id's slot index in its registry.
    pub fn index(self) -> usize {
        self.slot as usize
    }

    /// The slot generation this id was minted under (diagnostics; two ids
    /// for one string differing here span an unregister/re-register).
    pub fn generation(self) -> u32 {
        self.gen
    }

    /// The minting registry's identity token.
    pub(crate) fn token(self) -> u64 {
        self.token
    }
}

/// One tenant's adapters: per-layer LoRA pairs keyed by layer name.
#[derive(Clone, Debug)]
pub struct AdapterSet {
    id: String,
    layers: Vec<(String, LoraPair)>,
    index: HashMap<String, usize>,
}

impl AdapterSet {
    pub fn new(id: &str) -> AdapterSet {
        AdapterSet { id: id.to_string(), layers: Vec::new(), index: HashMap::new() }
    }

    /// Build from `(layer name, pair)` entries; duplicate layer names are
    /// rejected (requests address adapters by layer name).
    pub fn from_pairs(id: &str, pairs: Vec<(String, LoraPair)>) -> Result<AdapterSet, ServeError> {
        let mut set = AdapterSet::new(id);
        for (layer, pair) in pairs {
            set.insert(&layer, pair)?;
        }
        Ok(set)
    }

    pub fn insert(&mut self, layer: &str, pair: LoraPair) -> Result<(), ServeError> {
        if self.index.contains_key(layer) {
            return Err(ServeError::InvalidConfig {
                detail: format!("adapter '{}': duplicate entry for layer '{layer}'", self.id),
            });
        }
        self.index.insert(layer.to_string(), self.layers.len());
        self.layers.push((layer.to_string(), pair));
        Ok(())
    }

    pub fn id(&self) -> &str {
        &self.id
    }

    pub fn get(&self, layer: &str) -> Option<&LoraPair> {
        self.index.get(layer).map(|&i| &self.layers[i].1)
    }

    pub fn len(&self) -> usize {
        self.layers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.layers.is_empty()
    }

    /// `(layer name, pair)` entries in insertion order (the artifact writer
    /// iterates this, so save → load → save is byte-stable).
    pub fn entries(&self) -> impl Iterator<Item = (&str, &LoraPair)> {
        self.layers.iter().map(|(n, p)| (n.as_str(), p))
    }

    /// Adapter payload bytes (both factors of every pair, f64) — the unit
    /// of the registry's eviction budget.
    pub fn bytes(&self) -> usize {
        self.layers.iter().map(|(_, p)| p.bytes()).sum()
    }

    /// Validate every entry against `model`: the layer must exist and the
    /// pair must fit its base shape. Run at registration so admission and
    /// the kernel never see a misshapen adapter.
    pub fn check_against(&self, model: &PackedModel) -> Result<(), ServeError> {
        for (name, pair) in self.entries() {
            let layer = model
                .layer(name)
                .ok_or_else(|| ServeError::UnknownLayer { layer: name.to_string() })?;
            layer.check_adapter(pair).map_err(|e| match e {
                ServeError::ShapeMismatch { layer, detail } => ServeError::ShapeMismatch {
                    layer,
                    detail: format!("adapter '{}': {detail}", self.id),
                },
                other => other,
            })?;
        }
        Ok(())
    }

    /// Per-model-layer slot table: position `i` holds the index of this
    /// set's pair for model layer `i` (`None` = no delta there). Resolved
    /// once at registration; [`AdapterHandle::pair`] then serves the
    /// kernel's per-rider lookup as one array index — no string hashing on
    /// the hot path.
    fn resolve_against(&self, model: &PackedModel) -> Box<[Option<u32>]> {
        model
            .layers
            .iter()
            .map(|l| self.index.get(&l.name).map(|&i| i as u32))
            .collect()
    }
}

/// A registered adapter version plus its live pin count. One `ActiveAdapter`
/// per `register` call: hot-swapping an id creates a NEW `ActiveAdapter`,
/// so pins on the old version keep the old weights alive and coherent.
pub struct ActiveAdapter {
    set: AdapterSet,
    /// Model layer index → pair index in `set` (see
    /// [`AdapterSet::resolve_against`]).
    by_layer: Box<[Option<u32>]>,
    in_use: AtomicUsize,
}

impl ActiveAdapter {
    pub fn set(&self) -> &AdapterSet {
        &self.set
    }

    /// Live checkout count (queued + in-flight requests holding a handle).
    pub fn pins(&self) -> usize {
        self.in_use.load(Ordering::Acquire)
    }

    fn pair_at(&self, layer: LayerId) -> Option<&LoraPair> {
        match self.by_layer.get(layer.index()) {
            Some(&Some(i)) => Some(&self.set.layers[i as usize].1),
            _ => None,
        }
    }
}

/// RAII pin on one adapter version. Held by a request from admission until
/// its response is sent; while any handle exists the version cannot be
/// evicted and `unregister` of its id blocks (the drain).
pub struct AdapterHandle {
    active: Arc<ActiveAdapter>,
    shared: Arc<RegShared>,
}

impl AdapterHandle {
    pub fn set(&self) -> &AdapterSet {
        &self.active.set
    }

    /// This version's pair for the given model layer (`None` = the set
    /// carries no delta there). O(1) slot-table lookup — the kernel calls
    /// this once per rider per hop.
    pub fn pair(&self, layer: LayerId) -> Option<&LoraPair> {
        self.active.pair_at(layer)
    }

    /// Same underlying version? (Identity, not value, comparison — the
    /// engine keys batch groups on this.)
    pub fn same_version(&self, other: &AdapterHandle) -> bool {
        Arc::ptr_eq(&self.active, &other.active)
    }

    /// Opaque version identity token (two handles return the same token
    /// iff [`AdapterHandle::same_version`] holds).
    pub fn version_token(&self) -> usize {
        Arc::as_ptr(&self.active) as usize
    }
}

impl Clone for AdapterHandle {
    fn clone(&self) -> AdapterHandle {
        self.active.in_use.fetch_add(1, Ordering::AcqRel);
        AdapterHandle { active: Arc::clone(&self.active), shared: Arc::clone(&self.shared) }
    }
}

impl Drop for AdapterHandle {
    fn drop(&mut self) {
        if self.active.in_use.fetch_sub(1, Ordering::AcqRel) == 1 {
            // Last pin gone: take the registry lock before notifying so a
            // drain waiter cannot check the count and then miss the wakeup.
            let _guard = self.shared.state.lock().unwrap();
            self.shared.drained.notify_all();
        }
    }
}

struct Entry {
    active: Arc<ActiveAdapter>,
    /// Superseded versions of this id still pinned by queued/in-flight
    /// requests (hot-swap under load). Tracked so `unregister` drains the
    /// OLD weights too, and eviction never drops a version some request
    /// still holds. Pruned lazily on every hot-swap, checkout and stats
    /// call, so drained old weights do not linger past the id's next
    /// touch.
    superseded: Vec<Arc<ActiveAdapter>>,
    bytes: usize,
    /// Registry clock at the last checkout/registration — the LRU key.
    last_used: u64,
}

impl Entry {
    fn any_pinned(&self) -> bool {
        self.active.pins() > 0 || self.superseded.iter().any(|a| a.pins() > 0)
    }
}

/// One interned id: the name is permanent (ids stay resolvable), the entry
/// comes and goes with register/evict/unregister, and the generation
/// counts removals — ids minted under an older generation fail checkout.
struct Slot {
    name: String,
    /// Bumped every time the entry is REMOVED (unregister or eviction),
    /// never on hot-swap: the next register starts a new incarnation and
    /// ids from the dead one stop resolving ([`AdapterId`] docs).
    gen: u32,
    entry: Option<Entry>,
}

struct RegState {
    /// id string → slot index; grows monotonically (interning). A slot is
    /// never recycled for a DIFFERENT id — so memory here is bounded by
    /// the number of DISTINCT ids ever registered, not the number
    /// currently live — and the per-slot generation word scopes every
    /// minted [`AdapterId`] to one registration incarnation, so a stale
    /// handle can address neither another tenant NOR a later incarnation
    /// of its own id. Workloads that register unbounded unique ids (one
    /// per ephemeral job) still accrete dead slots; recycling slots for
    /// different ids remains future work.
    intern: HashMap<String, u32>,
    slots: Vec<Slot>,
    clock: u64,
    bytes_total: usize,
    evictions: usize,
}

struct RegShared {
    state: Mutex<RegState>,
    drained: Condvar,
}

/// What `register` did: the interned id to submit by, whether it
/// hot-swapped an existing id, and which adapters the byte budget pushed
/// out.
#[derive(Clone, Debug)]
pub struct RegisterOutcome {
    /// The interned id for the registered set — stable across hot-swaps.
    pub id: AdapterId,
    pub replaced: bool,
    pub evicted: Vec<String>,
}

/// Point-in-time registry counters.
#[derive(Clone, Debug, Default)]
pub struct RegistryStats {
    pub adapters: usize,
    pub bytes: usize,
    pub evictions: usize,
}

/// The live adapter set over ONE served model: id → current version,
/// LRU-evicted under `budget_bytes`. All operations are safe under
/// concurrent serving load; see the module docs for the hot-swap and drain
/// contracts. Binding the registry to its [`PackedModel`] means
/// registration always validates shapes — there is no unchecked side door
/// for a misshapen adapter to reach the kernel.
pub struct AdapterRegistry {
    model: Arc<PackedModel>,
    shared: Arc<RegShared>,
    budget_bytes: usize,
    /// Identity token stamped into every [`AdapterId`] this registry mints;
    /// checkout refuses ids carrying a different registry's token.
    token: u64,
}

impl AdapterRegistry {
    /// `budget_bytes` caps the total adapter payload held (pinned adapters
    /// are exempt from eviction, so a fully-pinned registry may transiently
    /// exceed the budget — by design, since evicting an adapter with queued
    /// requests would fail those requests for a cache policy's sake).
    pub fn new(model: Arc<PackedModel>, budget_bytes: usize) -> AdapterRegistry {
        AdapterRegistry {
            model,
            token: crate::serve::packed::next_identity_token(),
            shared: Arc::new(RegShared {
                state: Mutex::new(RegState {
                    intern: HashMap::new(),
                    slots: Vec::new(),
                    clock: 0,
                    bytes_total: 0,
                    evictions: 0,
                }),
                drained: Condvar::new(),
            }),
            budget_bytes: budget_bytes.max(1),
        }
    }

    /// The model this registry validates and resolves adapters against.
    pub fn model(&self) -> &PackedModel {
        &self.model
    }

    /// This registry's identity token (every id it mints carries it).
    pub(crate) fn token(&self) -> u64 {
        self.token
    }

    /// Validate `set` against the served model, insert (or hot-swap) it
    /// under its id, then evict least-recently-used UNPINNED adapters until
    /// the byte budget holds. A set larger than the whole budget is refused
    /// outright. Hot-swap does not wait for the old version's pins:
    /// in-flight requests finish on the old weights, new admissions see the
    /// new ones. The returned outcome carries the interned [`AdapterId`].
    pub fn register(&self, set: AdapterSet) -> Result<RegisterOutcome, ServeError> {
        set.check_against(&self.model)?;
        let bytes = set.bytes();
        if bytes > self.budget_bytes {
            return Err(ServeError::InvalidConfig {
                detail: format!(
                    "adapter '{}': {bytes} bytes exceed the whole registry budget of {} \
                     bytes",
                    set.id(),
                    self.budget_bytes
                ),
            });
        }
        let by_layer = set.resolve_against(&self.model);
        let name = set.id().to_string();
        let mut st = self.shared.state.lock().unwrap();
        let slot_idx = match st.intern.get(&name).copied() {
            Some(i) => i as usize,
            None => {
                let i = st.slots.len();
                st.intern.insert(name.clone(), i as u32);
                st.slots.push(Slot { name: name.clone(), gen: 0, entry: None });
                i
            }
        };
        // Hot-swap: still-pinned predecessor versions move onto the new
        // entry so unregister/eviction keep seeing their pins; fully
        // drained ones drop here.
        let mut replaced = false;
        let mut superseded = Vec::new();
        if let Some(old) = st.slots[slot_idx].entry.take() {
            st.bytes_total -= old.bytes;
            replaced = true;
            superseded.extend(old.superseded.into_iter().filter(|a| a.pins() > 0));
            if old.active.pins() > 0 {
                superseded.push(old.active);
            }
        }
        st.clock += 1;
        let stamp = st.clock;
        st.bytes_total += bytes;
        st.slots[slot_idx].entry = Some(Entry {
            active: Arc::new(ActiveAdapter { set, by_layer, in_use: AtomicUsize::new(0) }),
            superseded,
            bytes,
            last_used: stamp,
        });
        let mut evicted = Vec::new();
        while st.bytes_total > self.budget_bytes {
            // LRU among slots with NO pinned version (current or
            // superseded), never the id just registered.
            let victim = st
                .slots
                .iter()
                .enumerate()
                .filter(|&(i, _)| i != slot_idx)
                .filter_map(|(i, s)| s.entry.as_ref().map(|e| (i, e)))
                .filter(|(_, e)| !e.any_pinned())
                .min_by_key(|&(_, e)| e.last_used)
                .map(|(i, _)| i);
            match victim {
                Some(v) => {
                    let e = st.slots[v].entry.take().expect("victim had an entry");
                    // The incarnation died: ids minted under it must not
                    // resolve to whatever registers in the slot next.
                    st.slots[v].gen = st.slots[v].gen.wrapping_add(1);
                    st.bytes_total -= e.bytes;
                    st.evictions += 1;
                    evicted.push(st.slots[v].name.clone());
                }
                None => break, // everything else is pinned: tolerate over-budget
            }
        }
        Ok(RegisterOutcome {
            id: AdapterId { slot: slot_idx as u32, gen: st.slots[slot_idx].gen, token: self.token },
            replaced,
            evicted,
        })
    }

    /// Intern lookup: the [`AdapterId`] for a CURRENTLY REGISTERED id
    /// string (`None` when it never registered, was evicted, or was
    /// unregistered). The returned id stays stable across hot-swaps; an
    /// unregister/re-register of the same string mints a NEW generation,
    /// so re-resolve after re-registering ([`AdapterId`] docs).
    pub fn resolve(&self, name: &str) -> Option<AdapterId> {
        let st = self.shared.state.lock().unwrap();
        let i = st.intern.get(name).copied()?;
        let slot = &st.slots[i as usize];
        slot.entry.as_ref()?;
        Some(AdapterId { slot: i, gen: slot.gen, token: self.token })
    }

    /// The id string behind an interned handle (for error messages and
    /// diagnostics; works even while the slot is unregistered, and for
    /// ids from a DEAD generation — error naming must survive the very
    /// staleness that makes checkout refuse). `None` only for another
    /// registry's ids — their slot would name the wrong tenant here.
    pub fn name_of(&self, id: AdapterId) -> Option<String> {
        if id.token() != self.token {
            return None;
        }
        let st = self.shared.state.lock().unwrap();
        st.slots.get(id.index()).map(|s| s.name.clone())
    }

    /// Pin and return the current version of `id` (bumping its recency), or
    /// `None` if its slot is not currently registered OR the id was minted
    /// under a dead generation (the slot was unregistered/evicted and
    /// re-registered since — the tenant the id named is gone). O(1): one
    /// vector index plus one integer compare under the lock, no hashing.
    pub fn checkout(&self, id: AdapterId) -> Option<AdapterHandle> {
        if id.token() != self.token {
            return None; // another registry's handle: slot index means nothing here
        }
        let mut st = self.shared.state.lock().unwrap();
        st.clock += 1;
        let stamp = st.clock;
        let slot = st.slots.get_mut(id.index())?;
        if slot.gen != id.gen {
            return None; // a dead incarnation's handle must not reach the new tenant
        }
        let entry = slot.entry.as_mut()?;
        entry.superseded.retain(|a| a.pins() > 0); // free drained old weights
        entry.last_used = stamp;
        entry.active.in_use.fetch_add(1, Ordering::AcqRel);
        Some(AdapterHandle { active: Arc::clone(&entry.active), shared: Arc::clone(&self.shared) })
    }

    /// Name-resolving convenience checkout (admin paths and tests; the
    /// serving hot path resolves once and uses [`AdapterRegistry::checkout`]).
    pub fn checkout_named(&self, name: &str) -> Option<AdapterHandle> {
        self.checkout(self.resolve(name)?)
    }

    /// Remove `name` and BLOCK until every outstanding handle on EVERY
    /// version of it — the current one and any still-pinned hot-swap
    /// predecessors — drops: the per-adapter drain. On return no request,
    /// queued or in-flight, references any of the id's weights. New
    /// checkouts of the id fail the moment this is called (the entry is
    /// gone before the wait), so admission cannot re-pin a draining
    /// adapter. The interned slot itself survives, but its GENERATION is
    /// bumped: held [`AdapterId`]s stop resolving permanently — a later
    /// register of the same string starts a new incarnation that mints
    /// fresh ids, and the dead incarnation's handles fail checkout typed
    /// instead of silently addressing it ([`AdapterId`] docs).
    pub fn unregister(&self, name: &str) -> Result<(), ServeError> {
        let mut st = self.shared.state.lock().unwrap();
        let slot = st.intern.get(name).copied();
        let entry = match slot {
            Some(i) => {
                let taken = st.slots[i as usize].entry.take();
                if taken.is_some() {
                    // The incarnation is dead the moment the entry leaves;
                    // ids minted under it must never resolve again.
                    st.slots[i as usize].gen = st.slots[i as usize].gen.wrapping_add(1);
                }
                taken
            }
            None => None,
        };
        let entry =
            entry.ok_or_else(|| ServeError::UnknownAdapter { adapter: name.to_string() })?;
        st.bytes_total -= entry.bytes;
        while entry.any_pinned() {
            st = self.shared.drained.wait(st).unwrap();
        }
        Ok(())
    }

    pub fn contains(&self, name: &str) -> bool {
        let st = self.shared.state.lock().unwrap();
        st.intern
            .get(name)
            .is_some_and(|&i| st.slots[i as usize].entry.is_some())
    }

    /// Every interned slot's id string, in slot order — index `i` names
    /// slot `i`, whether or not it is currently registered. Telemetry
    /// labels its per-adapter attribution rows with this (attribution is
    /// indexed by slot, and a slot keeps its stats across
    /// unregister/re-register of the same id).
    pub fn slot_names(&self) -> Vec<String> {
        let st = self.shared.state.lock().unwrap();
        st.slots.iter().map(|s| s.name.clone()).collect()
    }

    /// Registered ids, alphabetical (diagnostics / demo output).
    pub fn ids(&self) -> Vec<String> {
        let st = self.shared.state.lock().unwrap();
        let mut ids: Vec<String> = st
            .slots
            .iter()
            .filter(|s| s.entry.is_some())
            .map(|s| s.name.clone())
            .collect();
        ids.sort();
        ids
    }

    pub fn stats(&self) -> RegistryStats {
        let mut st = self.shared.state.lock().unwrap();
        let mut adapters = 0usize;
        for s in st.slots.iter_mut() {
            if let Some(e) = s.entry.as_mut() {
                e.superseded.retain(|a| a.pins() > 0); // free drained old weights
                adapters += 1;
            }
        }
        RegistryStats { adapters, bytes: st.bytes_total, evictions: st.evictions }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::quant::{quantize_rtn, QuantState};
    use crate::serve::packed::PackedLayer;
    use crate::util::prng::Rng;

    /// One-layer model ("lin", 8→4) every test set fits.
    fn model() -> Arc<PackedModel> {
        let mut rng = Rng::new(900);
        let w = Matrix::randn(8, 4, 0.3, &mut rng);
        let q = QuantState::Int(quantize_rtn(&w, 4, 4));
        Arc::new(PackedModel::new(vec![PackedLayer::from_state("lin", &q).unwrap()]))
    }

    fn pair(m: usize, n: usize, r: usize, seed: u64) -> LoraPair {
        let mut rng = Rng::new(seed);
        LoraPair::new(Matrix::randn(m, r, 0.1, &mut rng), Matrix::randn(n, r, 0.1, &mut rng))
    }

    fn set(id: &str, seed: u64) -> AdapterSet {
        AdapterSet::from_pairs(id, vec![("lin".to_string(), pair(8, 4, 2, seed))]).unwrap()
    }

    #[test]
    fn set_lookup_and_bytes() {
        let s = set("t0", 1);
        assert_eq!(s.id(), "t0");
        assert_eq!(s.len(), 1);
        assert!(s.get("lin").is_some());
        assert!(s.get("nope").is_none());
        assert_eq!(s.bytes(), (8 * 2 + 4 * 2) * 8);
    }

    #[test]
    fn duplicate_layer_rejected() {
        let mut s = set("t0", 2);
        let err = s.insert("lin", pair(8, 4, 2, 3)).unwrap_err();
        assert!(matches!(err, ServeError::InvalidConfig { .. }), "{err:?}");
        assert!(format!("{err}").contains("duplicate"), "{err}");
    }

    #[test]
    fn register_checkout_unregister() {
        let reg = AdapterRegistry::new(model(), usize::MAX);
        let out = reg.register(set("a", 4)).unwrap();
        assert!(reg.contains("a"));
        assert_eq!(reg.resolve("a"), Some(out.id));
        assert_eq!(reg.name_of(out.id).as_deref(), Some("a"));
        {
            let h = reg.checkout(out.id).unwrap();
            assert_eq!(h.set().id(), "a");
            let lin = reg.model().resolve("lin").unwrap();
            assert!(h.pair(lin).is_some(), "resolved slot table must find the pair");
        }
        reg.unregister("a").unwrap();
        assert!(!reg.contains("a"));
        assert!(reg.resolve("a").is_none(), "unregistered ids stop resolving");
        assert!(reg.checkout(out.id).is_none(), "stale AdapterIds checkout to None");
        let err = reg.unregister("a").unwrap_err();
        assert!(matches!(&err, ServeError::UnknownAdapter { adapter } if adapter == "a"), "{err}");
        // Re-registering the same name revives the SAME interned slot but
        // under a NEW generation: the dead incarnation's id keeps failing
        // checkout instead of silently addressing the new tenant.
        let out2 = reg.register(set("a", 5)).unwrap();
        assert_eq!(out2.id.index(), out.id.index(), "intern slots are stable across unregister");
        assert_ne!(out2.id, out.id, "re-register mints a new generation");
        assert_eq!(out2.id.generation(), out.id.generation() + 1);
        assert!(reg.checkout(out.id).is_none(), "dead-generation ids stay dead");
        assert!(reg.checkout(out2.id).is_some(), "the new incarnation's id works");
        assert_eq!(reg.resolve("a"), Some(out2.id), "resolve returns the live generation");
        assert_eq!(
            reg.name_of(out.id).as_deref(),
            Some("a"),
            "error naming survives generation death"
        );
    }

    #[test]
    fn hot_swap_does_not_bump_the_generation() {
        let reg = AdapterRegistry::new(model(), usize::MAX);
        let first = reg.register(set("a", 30)).unwrap();
        let swapped = reg.register(set("a", 31)).unwrap();
        assert!(swapped.replaced);
        assert_eq!(swapped.id, first.id, "a swap is the SAME incarnation");
        assert!(reg.checkout(first.id).is_some(), "pre-swap ids keep resolving");
    }

    #[test]
    fn eviction_kills_the_generation() {
        let one = set("x", 32).bytes();
        let reg = AdapterRegistry::new(model(), 2 * one);
        let a = reg.register(set("a", 32)).unwrap();
        reg.register(set("b", 33)).unwrap();
        drop(reg.checkout_named("b").unwrap()); // a is now LRU
        let out = reg.register(set("c", 34)).unwrap();
        assert_eq!(out.evicted, vec!["a".to_string()]);
        assert!(reg.checkout(a.id).is_none(), "evicted ids stop resolving");
        let revived = reg.register(set("a", 35)).unwrap();
        assert_ne!(revived.id, a.id, "revival after eviction is a new incarnation");
        assert!(reg.checkout(a.id).is_none(), "the pre-eviction id stays dead");
        assert!(reg.checkout(revived.id).is_some());
    }

    #[test]
    fn misshapen_and_misnamed_sets_rejected_at_registration() {
        let reg = AdapterRegistry::new(model(), usize::MAX);
        let bad = AdapterSet::from_pairs("bad", vec![("lin".to_string(), pair(8, 9, 2, 6))])
            .unwrap();
        let err = reg.register(bad).unwrap_err();
        assert!(matches!(err, ServeError::ShapeMismatch { .. }), "{err:?}");
        assert!(format!("{err}").contains("does not fit base"), "{err}");
        let ghost =
            AdapterSet::from_pairs("g", vec![("ghost".to_string(), pair(8, 4, 2, 7))]).unwrap();
        let err = reg.register(ghost).unwrap_err();
        assert!(matches!(err, ServeError::UnknownLayer { .. }), "{err:?}");
    }

    #[test]
    fn lru_eviction_respects_recency() {
        let one = set("x", 5).bytes();
        let reg = AdapterRegistry::new(model(), 2 * one);
        reg.register(set("a", 5)).unwrap();
        reg.register(set("b", 6)).unwrap();
        drop(reg.checkout_named("a").unwrap()); // touch a: b is now LRU
        let out = reg.register(set("c", 7)).unwrap();
        assert_eq!(out.evicted, vec!["b".to_string()]);
        assert!(reg.contains("a") && reg.contains("c"));
        assert_eq!(reg.stats().evictions, 1);
    }

    #[test]
    fn pinned_adapter_never_evicted() {
        let one = set("x", 8).bytes();
        let reg = AdapterRegistry::new(model(), 2 * one);
        reg.register(set("a", 8)).unwrap();
        let _pin = reg.checkout_named("a").unwrap();
        reg.register(set("b", 9)).unwrap();
        drop(reg.checkout_named("b").unwrap()); // a is LRU but pinned
        let out = reg.register(set("c", 10)).unwrap();
        assert_eq!(out.evicted, vec!["b".to_string()], "pinned 'a' must be skipped");
        assert!(reg.contains("a"));
        // With everything pinned, over-budget is tolerated rather than
        // failing live requests.
        let _pin_c = reg.checkout_named("c").unwrap();
        let out = reg.register(set("d", 11)).unwrap();
        assert!(out.evicted.is_empty());
        assert!(reg.stats().bytes > 2 * one);
    }

    #[test]
    fn oversized_set_refused() {
        let reg = AdapterRegistry::new(model(), 8);
        let err = reg.register(set("big", 12)).unwrap_err();
        assert!(matches!(err, ServeError::InvalidConfig { .. }), "{err:?}");
        assert!(format!("{err}").contains("exceed the whole registry budget"), "{err}");
    }

    #[test]
    fn hot_swap_is_versioned_and_keeps_the_id() {
        let reg = AdapterRegistry::new(model(), usize::MAX);
        let first = reg.register(set("a", 13)).unwrap();
        let old = reg.checkout(first.id).unwrap();
        let out = reg.register(set("a", 14)).unwrap();
        assert!(out.replaced);
        assert_eq!(out.id, first.id, "hot-swap keeps the interned id");
        let new = reg.checkout(first.id).unwrap();
        assert!(!old.same_version(&new), "swap must mint a new version");
        assert_ne!(old.version_token(), new.version_token());
        // The old version's weights are still reachable through the pin.
        let (oa, na) = (old.set().get("lin").unwrap(), new.set().get("lin").unwrap());
        assert_ne!(oa.a.data, na.a.data, "distinct seeds ⇒ distinct weights");
    }

    #[test]
    fn unregister_drains_superseded_versions_too() {
        // A request pinned to the OLD version across a hot-swap must still
        // block unregister: the drain contract covers every version of the
        // id, not just the current one.
        let reg = Arc::new(AdapterRegistry::new(model(), usize::MAX));
        reg.register(set("a", 20)).unwrap();
        let old_pin = reg.checkout_named("a").unwrap();
        reg.register(set("a", 21)).unwrap(); // hot-swap; old version still pinned
        let done = Arc::new(AtomicUsize::new(0));
        let waiter = {
            let (reg, done) = (Arc::clone(&reg), Arc::clone(&done));
            std::thread::spawn(move || {
                reg.unregister("a").unwrap();
                done.store(1, Ordering::SeqCst);
            })
        };
        std::thread::sleep(std::time::Duration::from_millis(30));
        assert_eq!(
            done.load(Ordering::SeqCst),
            0,
            "drain must block on the superseded version's pin"
        );
        drop(old_pin);
        waiter.join().unwrap();
        assert_eq!(done.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn eviction_skips_entries_with_pinned_superseded_versions() {
        let one = set("x", 22).bytes();
        let reg = AdapterRegistry::new(model(), 2 * one);
        reg.register(set("a", 22)).unwrap();
        let old_pin = reg.checkout_named("a").unwrap();
        reg.register(set("a", 23)).unwrap(); // swap: current unpinned, old pinned
        reg.register(set("b", 24)).unwrap();
        drop(reg.checkout_named("b").unwrap()); // a is LRU but its old version is pinned
        let out = reg.register(set("c", 25)).unwrap();
        assert_eq!(out.evicted, vec!["b".to_string()], "superseded pin must protect 'a'");
        assert!(reg.contains("a"));
        drop(old_pin);
    }

    #[test]
    fn unregister_drains_outstanding_handles() {
        let reg = Arc::new(AdapterRegistry::new(model(), usize::MAX));
        reg.register(set("a", 15)).unwrap();
        let h = reg.checkout_named("a").unwrap();
        let h2 = h.clone();
        drop(h);
        let done = Arc::new(AtomicUsize::new(0));
        let waiter = {
            let (reg, done) = (Arc::clone(&reg), Arc::clone(&done));
            std::thread::spawn(move || {
                reg.unregister("a").unwrap();
                done.store(1, Ordering::SeqCst);
            })
        };
        std::thread::sleep(std::time::Duration::from_millis(30));
        assert_eq!(done.load(Ordering::SeqCst), 0, "drain must block while a handle lives");
        assert!(reg.checkout_named("a").is_none(), "draining adapter must refuse new pins");
        drop(h2);
        waiter.join().unwrap();
        assert_eq!(done.load(Ordering::SeqCst), 1);
    }
}

//! Read-only memory-mapped files for the zero-copy v3 artifact path.
//!
//! [`MappedFile`] maps a whole file `PROT_READ`/`MAP_PRIVATE` through a
//! raw `mmap(2)` declaration (the sandbox vendors no `libc` crate), and
//! falls back to an ordinary owned read on platforms without the call.
//! The artifact reader decides per section whether the mapping is usable
//! in place ([`MappedFile::is_zero_copy`] plus alignment/endianness
//! checks in `serve::artifact`); a fallback-read `MappedFile` still
//! serves the same bytes, just without the zero-copy property.
//!
//! Safety model: the mapping is private and read-only, and the pages
//! live exactly as long as the `MappedFile` (the packed layers hold it
//! in an `Arc`, so a served model can never outlive its pages). A file
//! truncated by another process AFTER mapping could still fault a load —
//! the standard mmap caveat — which is why serving artifacts are written
//! once and never rewritten in place (`ArtifactStore` writers create
//! fresh files).

use std::io;
use std::path::Path;

#[cfg(all(unix, any(target_os = "linux", target_os = "macos")))]
mod sys {
    use std::os::raw::{c_int, c_void};

    pub const PROT_READ: c_int = 1;
    pub const MAP_PRIVATE: c_int = 2;

    pub fn map_failed() -> *mut c_void {
        usize::MAX as *mut c_void
    }

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
    }
}

enum Backing {
    /// Live `mmap` pages (page-aligned base, unmapped on drop).
    #[cfg(all(unix, any(target_os = "linux", target_os = "macos")))]
    Mapped { ptr: *const u8, len: usize },
    /// Plain owned read — platforms without the syscall, or empty files
    /// (a zero-length `mmap` is `EINVAL`).
    Owned(Vec<u8>),
}

/// A whole file's bytes, memory-mapped when the platform allows it.
pub struct MappedFile {
    backing: Backing,
}

// The mapping is immutable (PROT_READ, MAP_PRIVATE) for its whole
// lifetime, so sharing the pages across threads is sound.
unsafe impl Send for MappedFile {}
unsafe impl Sync for MappedFile {}

impl MappedFile {
    /// Map `path` read-only; falls back to reading the file into an owned
    /// buffer when mapping is unavailable (non-unix, or an empty file).
    pub fn open(path: &Path) -> io::Result<MappedFile> {
        #[cfg(all(unix, any(target_os = "linux", target_os = "macos")))]
        {
            use std::os::unix::io::AsRawFd;
            let file = std::fs::File::open(path)?;
            let len = file.metadata()?.len() as usize;
            if len > 0 {
                let ptr = unsafe {
                    sys::mmap(
                        std::ptr::null_mut(),
                        len,
                        sys::PROT_READ,
                        sys::MAP_PRIVATE,
                        file.as_raw_fd(),
                        0,
                    )
                };
                if ptr != sys::map_failed() {
                    return Ok(MappedFile {
                        backing: Backing::Mapped { ptr: ptr as *const u8, len },
                    });
                }
                // mmap refused (exotic fs, resource limits): fall through
                // to the owned read — correctness over zero-copy.
            }
        }
        Ok(MappedFile { backing: Backing::Owned(std::fs::read(path)?) })
    }

    /// The file's bytes (mapped pages or the owned fallback buffer).
    pub fn bytes(&self) -> &[u8] {
        match &self.backing {
            #[cfg(all(unix, any(target_os = "linux", target_os = "macos")))]
            Backing::Mapped { ptr, len } => unsafe { std::slice::from_raw_parts(*ptr, *len) },
            Backing::Owned(v) => v,
        }
    }

    pub fn len(&self) -> usize {
        self.bytes().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// True when the bytes are live `mmap` pages (page-aligned base, no
    /// copy was made). False on the owned-read fallback.
    pub fn is_zero_copy(&self) -> bool {
        match &self.backing {
            #[cfg(all(unix, any(target_os = "linux", target_os = "macos")))]
            Backing::Mapped { .. } => true,
            Backing::Owned(_) => false,
        }
    }
}

impl Drop for MappedFile {
    fn drop(&mut self) {
        #[cfg(all(unix, any(target_os = "linux", target_os = "macos")))]
        if let Backing::Mapped { ptr, len } = self.backing {
            // Failure here is unrecoverable and harmless (address space
            // leak at worst); nothing sensible to do with the status.
            unsafe { sys::munmap(ptr as *mut std::os::raw::c_void, len) };
        }
    }
}

impl std::fmt::Debug for MappedFile {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MappedFile")
            .field("len", &self.len())
            .field("zero_copy", &self.is_zero_copy())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_and_reads_back_exact_bytes() {
        let path = std::env::temp_dir().join(format!("cloq_mmap_{}", std::process::id()));
        let payload: Vec<u8> = (0..9000u32).map(|i| (i % 251) as u8).collect();
        std::fs::write(&path, &payload).unwrap();
        let map = MappedFile::open(&path).unwrap();
        assert_eq!(map.len(), payload.len());
        assert_eq!(map.bytes(), &payload[..]);
        if cfg!(all(unix, any(target_os = "linux", target_os = "macos"))) {
            assert!(map.is_zero_copy(), "unix must take the mmap path");
            // The kernel hands back page-aligned mappings: the property
            // the v3 page-aligned section layout relies on.
            assert_eq!(map.bytes().as_ptr() as usize % 4096, 0);
        }
        drop(map);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn empty_file_uses_the_owned_fallback() {
        let path = std::env::temp_dir().join(format!("cloq_mmap_empty_{}", std::process::id()));
        std::fs::write(&path, b"").unwrap();
        let map = MappedFile::open(&path).unwrap();
        assert!(map.is_empty());
        assert!(!map.is_zero_copy(), "zero-length maps are EINVAL; must fall back");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_file_is_an_io_error() {
        let path = std::env::temp_dir().join("cloq_mmap_never_written");
        assert!(MappedFile::open(&path).is_err());
    }
}

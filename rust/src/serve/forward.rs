//! Full-model forward requests: the route/traversal layer on top of the
//! per-layer batching engine.
//!
//! A [`ModelRequest`] names a pre-validated [`Route`] of packed layers
//! (from `ServeEngine::route` / [`PackedModel::route`]) plus an optional
//! interned [`AdapterId`], and the engine decomposes it into per-layer
//! **hops**: when a micro-batch finishes, riders with more route left
//! re-enter the pending FIFO at their next layer instead of replying. Hops
//! from many concurrent model requests at the same depth therefore
//! coalesce into one grouped kernel call — the continuous-batching win —
//! while each request still computes the exact serial composition
//!
//! ```text
//!   y = f_{L-1}(… f_1(f_0(x)) …),   f_k = route[k]'s fused forward
//! ```
//!
//! Because a `Route` is resolved and chain-validated ONCE at construction
//! and cloning it is an `Arc` bump, submitting the same route for
//! thousands of requests costs no name resolution, no string clones, and
//! no per-request chain walk beyond integer compares.
//!
//! **Parity contract** (enforced by `rust/tests/parity_forward.rs`): the
//! pipelined traversal is bit-identical — 0 ULP — to the caller-driven
//! serial reference [`forward_route_serial`], whatever batches the hops
//! ride in, because each hop is one row of a grouped batch kernel that is
//! itself bit-identical to a serial [`PackedLayer::forward`] call (the
//! contract in `serve::packed`). The adapter is resolved to ONE pinned
//! version at admission and carried across every hop, so a hot-swap
//! mid-traversal can never mix adapter versions inside one response.
//!
//! A [`SessionRequest`] is the autoregressive-decode shape: up to `steps`
//! sequential full-model forwards with a caller-supplied step function
//! between them (`y_k → x_{k+1}`, e.g. sample-and-embed), run entirely
//! inside the engine so consecutive sessions keep coalescing with each
//! other at every depth. Per-session stats (hops, forwards, queue/compute
//! split, batch sizes seen) come back in the [`ModelResponse`].
//! `serve::generate` builds token-level decode on exactly this seam: its
//! step function IS tokenize→sample→re-embed, with per-token streaming,
//! stop conditions, and mid-session cancellation layered on top — reach
//! for [`ServeEngine::generate`] when the session's steps are tokens
//! rather than raw activations.
//!
//! [`ServeEngine::generate`]: crate::serve::engine::ServeEngine::generate
//!
//! Failures are typed ([`ServeError`]): a kernel panic on one hop fails
//! only the owning traversal with `WorkerPanic { hop: Some(_) }`, and a
//! misbehaving step function fails only its session with `StepFailed`.
//!
//! [`PackedLayer::forward`]: crate::serve::packed::PackedLayer::forward
//! [`PackedModel::route`]: crate::serve::packed::PackedModel::route

use std::time::Instant;

use crate::serve::adapters::{AdapterId, AdapterSet};
use crate::serve::completion::{CompleteFn, Completion, CompletionHandle, CompletionSender};
use crate::serve::error::ServeError;
use crate::serve::packed::{LayerId, PackedModel, Route};

/// One full-model forward request: the input activation, the validated
/// route it traverses, and the adapter applied wherever it carries a delta
/// (route layers without one run base-only).
pub struct ModelRequest {
    pub route: Route,
    pub adapter: Option<AdapterId>,
    pub x: Vec<f64>,
}

impl ModelRequest {
    /// Base-only full-model forward along `route`.
    pub fn new(route: Route, x: Vec<f64>) -> ModelRequest {
        ModelRequest { route, adapter: None, x }
    }

    /// Full-model forward routed through the interned adapter.
    pub fn with_adapter(route: Route, adapter: AdapterId, x: Vec<f64>) -> ModelRequest {
        ModelRequest { route, adapter: Some(adapter), x }
    }
}

/// The step function between a session's forwards: called with the number
/// of completed forwards (1-based) and the final activation of the last
/// one; returns the next forward's input, or `None` to end the session
/// early. Runs on a kernel worker — panics are caught and fail only the
/// owning session.
pub type StepFn = Box<dyn FnMut(usize, &[f64]) -> Option<Vec<f64>> + Send + 'static>;

/// A multi-step session: up to `steps` sequential full-model forwards with
/// [`StepFn`] bridging each pair — the autoregressive-decode request shape.
/// The adapter (like a [`ModelRequest`]'s) is pinned once at admission and
/// held for the whole session.
pub struct SessionRequest {
    pub route: Route,
    pub adapter: Option<AdapterId>,
    pub x0: Vec<f64>,
    pub steps: usize,
    pub step: StepFn,
}

impl SessionRequest {
    pub fn new(route: Route, x0: Vec<f64>, steps: usize, step: StepFn) -> SessionRequest {
        SessionRequest { route, adapter: None, x0, steps, step }
    }

    pub fn with_adapter(
        route: Route,
        adapter: AdapterId,
        x0: Vec<f64>,
        steps: usize,
        step: StepFn,
    ) -> SessionRequest {
        SessionRequest { route, adapter: Some(adapter), x0, steps, step }
    }
}

/// A completed model request or session: the final activation plus the
/// traversal's stats.
#[derive(Clone, Debug)]
pub struct ModelResponse {
    /// Output of the last route layer of the last completed forward.
    pub y: Vec<f64>,
    /// Forward passes completed (1 for a plain [`ModelRequest`]; ≤ `steps`
    /// for a session whose step function ended it early).
    pub forwards: usize,
    /// Layer hops executed (`forwards · route_len`).
    pub hops: usize,
    /// Summed FIFO wait across all hops.
    pub queue_s: f64,
    /// Summed kernel time of every micro-batch a hop rode in.
    pub compute_s: f64,
    /// Admission → reply.
    pub wall_s: f64,
    /// Largest micro-batch any hop rode in — >1 means the traversal
    /// actually coalesced with other traffic.
    pub max_batch_seen: usize,
    /// Hops that rode a batch mixing more than one adapter group.
    pub mixed_hops: usize,
    /// This request's telemetry trace id (0 when tracing is disabled);
    /// look the span timeline up in `TelemetrySnapshot::recent_traces`.
    pub trace_id: u64,
}

/// Handle to a submitted [`ModelRequest`] / [`SessionRequest`]; resolves to
/// its [`ModelResponse`] or a typed [`ServeError`]. Implements
/// [`Completion`] — poll with [`try_wait`](Completion::try_wait) or attach
/// a callback with [`on_complete`](Completion::on_complete) instead of
/// parking a thread.
pub struct ModelTicket {
    cell: CompletionHandle<ModelResponse>,
}

impl ModelTicket {
    pub(crate) fn new(cell: CompletionHandle<ModelResponse>) -> ModelTicket {
        ModelTicket { cell }
    }

    /// Block until the engine answers. An engine that dropped before
    /// answering reports [`ServeError::ShuttingDown`].
    pub fn wait(self) -> Result<ModelResponse, ServeError> {
        self.cell.wait()
    }

    /// [`wait`](ModelTicket::wait) with a deadline:
    /// [`ServeError::Timeout`] once `timeout` elapses with no reply.
    ///
    /// The deadline is a CALLER-side contract only — the traversal is not
    /// cancelled. It still holds its live backpressure slot, still
    /// executes every remaining hop (and session step), and still counts
    /// in `model_requests` / telemetry when it completes; its reply is
    /// dropped because this ticket (the only receiver) is consumed. Use
    /// it to bound caller latency, not engine load.
    pub fn wait_timeout(self, timeout: std::time::Duration) -> Result<ModelResponse, ServeError> {
        self.cell.wait_timeout(timeout)
    }
}

impl Completion for ModelTicket {
    type Output = ModelResponse;

    fn try_wait(&mut self) -> Option<Result<ModelResponse, ServeError>> {
        self.cell.try_take()
    }

    fn on_complete(self, f: CompleteFn<ModelResponse>) {
        self.cell.on_complete(f);
    }

    fn wait(self) -> Result<ModelResponse, ServeError> {
        ModelTicket::wait(self)
    }

    fn wait_timeout(self, timeout: std::time::Duration) -> Result<ModelResponse, ServeError> {
        ModelTicket::wait_timeout(self, timeout)
    }
}

/// The caller-driven serial reference the parity suite pins the pipelined
/// traversal against: one [`PackedLayer::forward`] per route layer, the
/// adapter's pair applied wherever it carries one. This is also exactly
/// what a caller without `submit_model` has to do by hand — the throughput
/// comparison in `benches/bench_forward.rs`.
///
/// `route` must have been built against `model` and `x` must match the
/// head layer's input width (the kernel asserts it, like any direct
/// [`PackedLayer::forward`] call).
///
/// [`PackedLayer::forward`]: crate::serve::packed::PackedLayer::forward
pub fn forward_route_serial(
    model: &PackedModel,
    route: &Route,
    adapter: Option<&AdapterSet>,
    x: &[f64],
) -> Vec<f64> {
    let mut cur = x.to_vec();
    for &id in route.as_ids() {
        let layer = model
            .get(id)
            .expect("forward_route_serial: route was built against a different (larger) model");
        cur = layer.forward(&cur, adapter.and_then(|s| s.get(&layer.name)));
    }
    cur
}

/// What a finished hop does next (returned by [`Traversal::absorb_hop`]).
pub(crate) enum HopOutcome {
    /// More route (or another forward) left: re-enter the FIFO at `layer`
    /// with input `x`.
    Reenter { layer: LayerId, x: Vec<f64>, traversal: Box<Traversal> },
    /// The traversal replied (success or failure) and released its slot.
    Replied { ok: bool, forwards: usize },
}

/// Engine-internal state of one in-flight model request / session: where
/// it is on its route, how many forwards remain, and the stats accumulated
/// so far. Owned by the rider's `Pending` hop; consumed on reply.
pub(crate) struct Traversal {
    route: Route,
    /// Index into `route` of the hop just executed.
    hop: usize,
    forwards_done: usize,
    steps: usize,
    step: Option<StepFn>,
    t_admit: Instant,
    hops_done: usize,
    queue_s: f64,
    compute_s: f64,
    max_batch_seen: usize,
    mixed_hops: usize,
    /// Telemetry trace id stamped into the reply (0 = tracing disabled;
    /// the trace buffer itself rides the owning `Pending` hop).
    trace_id: u64,
    tx: CompletionSender<ModelResponse>,
}

impl Traversal {
    /// `steps == 1` may omit the step fn; multi-step sessions must carry
    /// one (enforced by the public constructors, asserted here).
    pub(crate) fn new(
        route: Route,
        steps: usize,
        step: Option<StepFn>,
        tx: CompletionSender<ModelResponse>,
        t_admit: Instant,
        trace_id: u64,
    ) -> Traversal {
        assert!(steps >= 1, "traversal with zero forwards");
        assert!(!route.is_empty(), "traversal with an empty route");
        assert!(steps == 1 || step.is_some(), "multi-step session without a step fn");
        Traversal {
            route,
            hop: 0,
            forwards_done: 0,
            steps,
            step,
            t_admit,
            hops_done: 0,
            queue_s: 0.0,
            compute_s: 0.0,
            max_batch_seen: 0,
            mixed_hops: 0,
            trace_id,
            tx,
        }
    }

    /// Hops already executed (the engine names the failing hop in kernel
    /// panic errors).
    pub(crate) fn hops_done(&self) -> usize {
        self.hops_done
    }

    /// Fold one executed hop's result into the traversal and decide what
    /// happens next: re-enter at the next route layer, start the next
    /// forward through the step fn, or reply. `rows_of` maps a layer id
    /// to its input width (validates step-fn outputs before they re-enter).
    /// Step-fn panics are caught here and fail only this traversal.
    pub(crate) fn absorb_hop(
        mut self: Box<Self>,
        y: Vec<f64>,
        queue_s: f64,
        compute_s: f64,
        batch: usize,
        groups: usize,
        rows_of: &dyn Fn(LayerId) -> usize,
    ) -> HopOutcome {
        self.hops_done += 1;
        self.queue_s += queue_s;
        self.compute_s += compute_s;
        self.max_batch_seen = self.max_batch_seen.max(batch);
        if groups > 1 {
            self.mixed_hops += 1;
        }
        self.hop += 1;
        if self.hop < self.route.len() {
            let layer = self.route.as_ids()[self.hop];
            return HopOutcome::Reenter { layer, x: y, traversal: self };
        }
        // Route exhausted: one full forward pass is done.
        self.forwards_done += 1;
        if self.forwards_done == self.steps {
            return self.reply_ok(y);
        }
        let k = self.forwards_done;
        let step = self.step.as_mut().expect("checked at construction");
        match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| step(k, &y))) {
            Err(_) => self.reply_err(ServeError::StepFailed {
                forward: k,
                detail: "the step function panicked".to_string(),
            }),
            Ok(None) => self.reply_ok(y), // caller-requested early stop
            Ok(Some(next_x)) => {
                let head = self.route.as_ids()[0];
                let need = rows_of(head);
                if next_x.len() != need {
                    return self.reply_err(ServeError::StepFailed {
                        forward: k,
                        detail: format!(
                            "returned {} values but the route head takes {need} features",
                            next_x.len()
                        ),
                    });
                }
                self.hop = 0;
                HopOutcome::Reenter { layer: head, x: next_x, traversal: self }
            }
        }
    }

    /// Fail the traversal (kernel panic on one of its hops); returns the
    /// forwards it had completed, for the engine's counters.
    pub(crate) fn fail(self: Box<Self>, e: ServeError) -> usize {
        let forwards = self.forwards_done;
        let _ = self.tx.send(Err(e));
        forwards
    }

    fn reply_ok(self: Box<Self>, y: Vec<f64>) -> HopOutcome {
        let forwards = self.forwards_done;
        let resp = ModelResponse {
            y,
            forwards,
            hops: self.hops_done,
            queue_s: self.queue_s,
            compute_s: self.compute_s,
            wall_s: self.t_admit.elapsed().as_secs_f64(),
            max_batch_seen: self.max_batch_seen,
            mixed_hops: self.mixed_hops,
            trace_id: self.trace_id,
        };
        let _ = self.tx.send(Ok(resp)); // requester may have given up; fine
        HopOutcome::Replied { ok: true, forwards }
    }

    fn reply_err(self: Box<Self>, e: ServeError) -> HopOutcome {
        let forwards = self.forwards_done;
        let _ = self.tx.send(Err(e));
        HopOutcome::Replied { ok: false, forwards }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::completion;
    use crate::linalg::Matrix;
    use crate::quant::{quantize_rtn, QuantState};
    use crate::serve::packed::PackedLayer;
    use crate::util::prng::Rng;

    fn chain_model(seed: u64) -> PackedModel {
        // 12 → 8 → 20 → 12: chainable, and the tail matches the head so a
        // session can loop with an identity-shaped step.
        let mut rng = Rng::new(seed);
        let mut layers = Vec::new();
        for (name, m, n) in [("a", 12usize, 8usize), ("b", 8, 20), ("c", 20, 12)] {
            let w = Matrix::randn(m, n, 0.3, &mut rng);
            let q = QuantState::Int(quantize_rtn(&w, 4, 8));
            layers.push(PackedLayer::from_state(name, &q).unwrap());
        }
        PackedModel::new(layers)
    }

    #[test]
    fn serial_reference_composes_layer_forwards() {
        let m = chain_model(900);
        let route = m.route(&["a", "b", "c"]).unwrap();
        let x = Rng::new(901).gauss_vec(12);
        let y = forward_route_serial(&m, &route, None, &x);
        let mut expect = x.clone();
        for name in ["a", "b", "c"] {
            expect = m.layer(name).unwrap().forward(&expect, None);
        }
        assert_eq!(y, expect);
        assert_eq!(y.len(), 12);
    }

    #[test]
    fn broken_routes_fail_at_construction() {
        // Route validation happens ONCE, when the Route is built — the
        // serial reference and the engine then consume only valid routes.
        let m = chain_model(902);
        let err = m.route(&["a", "c"]).unwrap_err();
        assert!(matches!(err, ServeError::BadRoute { .. }), "{err:?}");
        assert!(format!("{err}").contains("route break"), "{err}");
        let err = m.route(&["a", "nope"]).unwrap_err();
        assert!(matches!(&err, ServeError::UnknownLayer { layer } if layer == "nope"), "{err}");
    }

    fn test_route(ids: &[usize]) -> Route {
        Route::from_validated(ids.iter().map(|&i| LayerId::new(i)).collect())
    }

    #[test]
    fn traversal_walks_route_then_replies() {
        let (tx, rx) = completion::channel();
        let t0 = Instant::now();
        let mut tr = Box::new(Traversal::new(test_route(&[0, 1, 2]), 1, None, tx, t0, 0));
        let rows_of = |_: LayerId| 4usize;
        for expect_layer in [1usize, 2] {
            match tr.absorb_hop(vec![0.0; 4], 1e-6, 2e-6, 3, 1, &rows_of) {
                HopOutcome::Reenter { layer, traversal, .. } => {
                    assert_eq!(layer.index(), expect_layer);
                    tr = traversal;
                }
                HopOutcome::Replied { .. } => panic!("route not exhausted yet"),
            }
        }
        match tr.absorb_hop(vec![7.0; 4], 1e-6, 2e-6, 5, 2, &rows_of) {
            HopOutcome::Replied { ok, forwards } => {
                assert!(ok);
                assert_eq!(forwards, 1);
            }
            HopOutcome::Reenter { .. } => panic!("route exhausted"),
        }
        let resp = rx.wait().unwrap();
        assert_eq!(resp.y, vec![7.0; 4]);
        assert_eq!(resp.hops, 3);
        assert_eq!(resp.forwards, 1);
        assert_eq!(resp.max_batch_seen, 5);
        assert_eq!(resp.mixed_hops, 1);
        assert!((resp.queue_s - 3e-6).abs() < 1e-12);
    }

    #[test]
    fn session_step_bridges_forwards_and_can_stop_early() {
        let (tx, rx) = completion::channel();
        let step: StepFn =
            Box::new(|k, y| if k < 2 { Some(y.iter().map(|v| v + 1.0).collect()) } else { None });
        let mut tr =
            Box::new(Traversal::new(test_route(&[0]), 10, Some(step), tx, Instant::now(), 0));
        let rows_of = |_: LayerId| 2usize;
        // Forward 1 done → step runs → re-enter at the route head.
        tr = match tr.absorb_hop(vec![1.0, 1.0], 0.0, 0.0, 1, 1, &rows_of) {
            HopOutcome::Reenter { layer, x, traversal } => {
                assert_eq!(layer.index(), 0);
                assert_eq!(x, vec![2.0, 2.0]);
                traversal
            }
            _ => panic!("step must continue the session"),
        };
        // Forward 2 done → step returns None → early stop at forwards=2.
        match tr.absorb_hop(vec![5.0, 5.0], 0.0, 0.0, 1, 1, &rows_of) {
            HopOutcome::Replied { ok, forwards } => {
                assert!(ok);
                assert_eq!(forwards, 2);
            }
            _ => panic!("step returned None: session must end"),
        }
        let resp = rx.wait().unwrap();
        assert_eq!(resp.forwards, 2);
        assert_eq!(resp.hops, 2);
        assert_eq!(resp.y, vec![5.0, 5.0]);
    }

    #[test]
    fn misshapen_step_output_fails_the_session_actionably() {
        let (tx, rx) = completion::channel();
        let step: StepFn = Box::new(|_, _| Some(vec![0.0; 99]));
        let tr =
            Box::new(Traversal::new(test_route(&[0]), 3, Some(step), tx, Instant::now(), 0));
        match tr.absorb_hop(vec![0.0; 2], 0.0, 0.0, 1, 1, &|_| 2usize) {
            HopOutcome::Replied { ok, forwards } => {
                assert!(!ok);
                assert_eq!(forwards, 1);
            }
            _ => panic!("bad step output must fail the session"),
        }
        let err = rx.wait().unwrap_err();
        assert!(matches!(&err, ServeError::StepFailed { forward: 1, .. }), "{err:?}");
        let msg = format!("{err}");
        assert!(msg.contains("99 values"), "{msg}");
        assert!(msg.contains("takes 2 features"), "{msg}");
    }

    #[test]
    fn panicking_step_fails_only_its_session() {
        let (tx, rx) = completion::channel();
        let step: StepFn = Box::new(|_, _| panic!("injected step panic"));
        let tr =
            Box::new(Traversal::new(test_route(&[0]), 2, Some(step), tx, Instant::now(), 0));
        match tr.absorb_hop(vec![0.0; 2], 0.0, 0.0, 1, 1, &|_| 2usize) {
            HopOutcome::Replied { ok, .. } => assert!(!ok),
            _ => panic!("step panic must fail the session"),
        }
        let err = rx.wait().unwrap_err();
        assert!(matches!(err, ServeError::StepFailed { .. }), "{err:?}");
        assert!(format!("{err}").contains("step function panicked"), "{err}");
    }
}

//! Full-model forward requests: the route/traversal layer on top of the
//! per-layer batching engine.
//!
//! A [`ModelRequest`] names an ordered **route** of packed layers (from
//! [`crate::model::ModelConfig::forward_route`] or hand-built) plus an
//! optional adapter, and the engine decomposes it into per-layer **hops**:
//! when a micro-batch finishes, riders with more route left re-enter the
//! pending FIFO at their next layer instead of replying. Hops from many
//! concurrent model requests at the same depth therefore coalesce into one
//! grouped kernel call — the continuous-batching win — while each request
//! still computes the exact serial composition
//!
//! ```text
//!   y = f_{L-1}(… f_1(f_0(x)) …),   f_k = route[k]'s fused forward
//! ```
//!
//! **Parity contract** (enforced by `rust/tests/parity_forward.rs`): the
//! pipelined traversal is bit-identical — 0 ULP — to the caller-driven
//! serial reference [`forward_route_serial`], whatever batches the hops
//! ride in, because each hop is one row of a grouped batch kernel that is
//! itself bit-identical to a serial [`PackedLayer::forward`] call (the
//! contract in `serve::packed`). The adapter is resolved to ONE pinned
//! version at admission and carried across every hop, so a hot-swap
//! mid-traversal can never mix adapter versions inside one response —
//! PR 3's consistency guarantee extends to whole-model requests.
//!
//! A [`SessionRequest`] is the autoregressive-decode shape: up to `steps`
//! sequential full-model forwards with a caller-supplied step function
//! between them (`y_k → x_{k+1}`, e.g. sample-and-embed), run entirely
//! inside the engine so consecutive sessions keep coalescing with each
//! other at every depth. Per-session stats (hops, forwards, queue/compute
//! split, batch sizes seen) come back in the [`ModelResponse`].
//!
//! [`PackedLayer::forward`]: crate::serve::packed::PackedLayer::forward

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use crate::serve::adapters::AdapterSet;
use crate::serve::packed::PackedModel;

/// One full-model forward request: the input activation, the ordered layer
/// route it traverses, and the adapter applied wherever it carries a delta
/// (route layers without one run base-only).
pub struct ModelRequest {
    pub route: Vec<String>,
    pub adapter: Option<String>,
    pub x: Vec<f64>,
}

impl ModelRequest {
    /// Base-only full-model forward along `route`.
    pub fn new(route: Vec<String>, x: Vec<f64>) -> ModelRequest {
        ModelRequest { route, adapter: None, x }
    }

    /// Full-model forward routed through the named adapter.
    pub fn with_adapter(route: Vec<String>, adapter: &str, x: Vec<f64>) -> ModelRequest {
        ModelRequest { route, adapter: Some(adapter.to_string()), x }
    }
}

/// The step function between a session's forwards: called with the number
/// of completed forwards (1-based) and the final activation of the last
/// one; returns the next forward's input, or `None` to end the session
/// early. Runs on a kernel worker — panics are caught and fail only the
/// owning session.
pub type StepFn = Box<dyn FnMut(usize, &[f64]) -> Option<Vec<f64>> + Send + 'static>;

/// A multi-step session: up to `steps` sequential full-model forwards with
/// [`StepFn`] bridging each pair — the autoregressive-decode request shape.
/// The adapter (like a [`ModelRequest`]'s) is pinned once at admission and
/// held for the whole session.
pub struct SessionRequest {
    pub route: Vec<String>,
    pub adapter: Option<String>,
    pub x0: Vec<f64>,
    pub steps: usize,
    pub step: StepFn,
}

impl SessionRequest {
    pub fn new(route: Vec<String>, x0: Vec<f64>, steps: usize, step: StepFn) -> SessionRequest {
        SessionRequest { route, adapter: None, x0, steps, step }
    }

    pub fn with_adapter(
        route: Vec<String>,
        adapter: &str,
        x0: Vec<f64>,
        steps: usize,
        step: StepFn,
    ) -> SessionRequest {
        SessionRequest { route, adapter: Some(adapter.to_string()), x0, steps, step }
    }
}

/// A completed model request or session: the final activation plus the
/// traversal's stats.
#[derive(Clone, Debug)]
pub struct ModelResponse {
    /// Output of the last route layer of the last completed forward.
    pub y: Vec<f64>,
    /// Forward passes completed (1 for a plain [`ModelRequest`]; ≤ `steps`
    /// for a session whose step function ended it early).
    pub forwards: usize,
    /// Layer hops executed (`forwards · route_len`).
    pub hops: usize,
    /// Summed FIFO wait across all hops.
    pub queue_s: f64,
    /// Summed kernel time of every micro-batch a hop rode in.
    pub compute_s: f64,
    /// Admission → reply.
    pub wall_s: f64,
    /// Largest micro-batch any hop rode in — >1 means the traversal
    /// actually coalesced with other traffic.
    pub max_batch_seen: usize,
    /// Hops that rode a batch mixing more than one adapter group.
    pub mixed_hops: usize,
}

/// Handle to a submitted [`ModelRequest`] / [`SessionRequest`]; resolves to
/// its [`ModelResponse`].
pub struct ModelTicket {
    rx: mpsc::Receiver<anyhow::Result<ModelResponse>>,
}

impl ModelTicket {
    pub(crate) fn new(rx: mpsc::Receiver<anyhow::Result<ModelResponse>>) -> ModelTicket {
        ModelTicket { rx }
    }

    /// Block until the engine answers (or report that it shut down first).
    pub fn wait(self) -> anyhow::Result<ModelResponse> {
        self.rx
            .recv()
            .map_err(|_| anyhow::anyhow!("serve engine dropped before answering"))?
    }
}

/// The caller-driven serial reference the parity suite pins the pipelined
/// traversal against: one [`PackedLayer::forward`] per route layer, the
/// adapter's pair applied wherever it carries one. This is also exactly
/// what a caller without `submit_model` has to do by hand — the throughput
/// comparison in `benches/bench_forward.rs`.
///
/// [`PackedLayer::forward`]: crate::serve::packed::PackedLayer::forward
pub fn forward_route_serial(
    model: &PackedModel,
    route: &[String],
    adapter: Option<&AdapterSet>,
    x: &[f64],
) -> anyhow::Result<Vec<f64>> {
    let idxs = model.route_indices(route)?;
    let mut cur = x.to_vec();
    for &i in &idxs {
        let layer = &model.layers[i];
        cur = layer.forward(&cur, adapter.and_then(|s| s.get(&layer.name)));
    }
    Ok(cur)
}

/// What a finished hop does next (returned by [`Traversal::absorb_hop`]).
pub(crate) enum HopOutcome {
    /// More route (or another forward) left: re-enter the FIFO at `layer`
    /// with input `x`.
    Reenter { layer: usize, x: Vec<f64>, traversal: Box<Traversal> },
    /// The traversal replied (success or failure) and released its slot.
    Replied { ok: bool, forwards: usize },
}

/// Engine-internal state of one in-flight model request / session: where
/// it is on its route, how many forwards remain, and the stats accumulated
/// so far. Owned by the rider's `Pending` hop; consumed on reply.
pub(crate) struct Traversal {
    route: Arc<Vec<usize>>,
    /// Index into `route` of the hop just executed.
    hop: usize,
    forwards_done: usize,
    steps: usize,
    step: Option<StepFn>,
    t_admit: Instant,
    hops_done: usize,
    queue_s: f64,
    compute_s: f64,
    max_batch_seen: usize,
    mixed_hops: usize,
    tx: mpsc::Sender<anyhow::Result<ModelResponse>>,
}

impl Traversal {
    /// `steps == 1` may omit the step fn; multi-step sessions must carry
    /// one (enforced by the public constructors, asserted here).
    pub(crate) fn new(
        route: Arc<Vec<usize>>,
        steps: usize,
        step: Option<StepFn>,
        tx: mpsc::Sender<anyhow::Result<ModelResponse>>,
        t_admit: Instant,
    ) -> Traversal {
        assert!(steps >= 1, "traversal with zero forwards");
        assert!(!route.is_empty(), "traversal with an empty route");
        assert!(steps == 1 || step.is_some(), "multi-step session without a step fn");
        Traversal {
            route,
            hop: 0,
            forwards_done: 0,
            steps,
            step,
            t_admit,
            hops_done: 0,
            queue_s: 0.0,
            compute_s: 0.0,
            max_batch_seen: 0,
            mixed_hops: 0,
            tx,
        }
    }

    /// Hops already executed (the engine names the failing hop in kernel
    /// panic errors).
    pub(crate) fn hops_done(&self) -> usize {
        self.hops_done
    }

    /// Fold one executed hop's result into the traversal and decide what
    /// happens next: re-enter at the next route layer, start the next
    /// forward through the step fn, or reply. `rows_of` maps a layer index
    /// to its input width (validates step-fn outputs before they re-enter).
    /// Step-fn panics are caught here and fail only this traversal.
    pub(crate) fn absorb_hop(
        mut self: Box<Self>,
        y: Vec<f64>,
        queue_s: f64,
        compute_s: f64,
        batch: usize,
        groups: usize,
        rows_of: &dyn Fn(usize) -> usize,
    ) -> HopOutcome {
        self.hops_done += 1;
        self.queue_s += queue_s;
        self.compute_s += compute_s;
        self.max_batch_seen = self.max_batch_seen.max(batch);
        if groups > 1 {
            self.mixed_hops += 1;
        }
        self.hop += 1;
        if self.hop < self.route.len() {
            let layer = self.route[self.hop];
            return HopOutcome::Reenter { layer, x: y, traversal: self };
        }
        // Route exhausted: one full forward pass is done.
        self.forwards_done += 1;
        if self.forwards_done == self.steps {
            return self.reply_ok(y);
        }
        let k = self.forwards_done;
        let step = self.step.as_mut().expect("checked at construction");
        match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| step(k, &y))) {
            Err(_) => self.reply_err(anyhow::anyhow!(
                "session step function panicked after forward {k}"
            )),
            Ok(None) => self.reply_ok(y), // caller-requested early stop
            Ok(Some(next_x)) => {
                let head = self.route[0];
                let need = rows_of(head);
                if next_x.len() != need {
                    return self.reply_err(anyhow::anyhow!(
                        "session step after forward {k} returned {} values but the route \
                         head takes {need} features",
                        next_x.len()
                    ));
                }
                self.hop = 0;
                HopOutcome::Reenter { layer: head, x: next_x, traversal: self }
            }
        }
    }

    /// Fail the traversal (kernel panic on one of its hops); returns the
    /// forwards it had completed, for the engine's counters.
    pub(crate) fn fail(self: Box<Self>, e: anyhow::Error) -> usize {
        let forwards = self.forwards_done;
        let _ = self.tx.send(Err(e));
        forwards
    }

    fn reply_ok(self: Box<Self>, y: Vec<f64>) -> HopOutcome {
        let forwards = self.forwards_done;
        let resp = ModelResponse {
            y,
            forwards,
            hops: self.hops_done,
            queue_s: self.queue_s,
            compute_s: self.compute_s,
            wall_s: self.t_admit.elapsed().as_secs_f64(),
            max_batch_seen: self.max_batch_seen,
            mixed_hops: self.mixed_hops,
        };
        let _ = self.tx.send(Ok(resp)); // requester may have given up; fine
        HopOutcome::Replied { ok: true, forwards }
    }

    fn reply_err(self: Box<Self>, e: anyhow::Error) -> HopOutcome {
        let forwards = self.forwards_done;
        let _ = self.tx.send(Err(e));
        HopOutcome::Replied { ok: false, forwards }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::quant::{quantize_rtn, QuantState};
    use crate::serve::packed::PackedLayer;
    use crate::util::prng::Rng;

    fn chain_model(seed: u64) -> PackedModel {
        // 12 → 8 → 20 → 12: chainable, and the tail matches the head so a
        // session can loop with an identity-shaped step.
        let mut rng = Rng::new(seed);
        let mut layers = Vec::new();
        for (name, m, n) in [("a", 12usize, 8usize), ("b", 8, 20), ("c", 20, 12)] {
            let w = Matrix::randn(m, n, 0.3, &mut rng);
            let q = QuantState::Int(quantize_rtn(&w, 4, 8));
            layers.push(PackedLayer::from_state(name, &q).unwrap());
        }
        PackedModel::new(layers)
    }

    fn names(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn serial_reference_composes_layer_forwards() {
        let m = chain_model(900);
        let x = Rng::new(901).gauss_vec(12);
        let y = forward_route_serial(&m, &names(&["a", "b", "c"]), None, &x).unwrap();
        let mut expect = x.clone();
        for name in ["a", "b", "c"] {
            expect = m.layer(name).unwrap().forward(&expect, None);
        }
        assert_eq!(y, expect);
        assert_eq!(y.len(), 12);
    }

    #[test]
    fn serial_reference_rejects_broken_routes() {
        let m = chain_model(902);
        let x = vec![0.0; 12];
        let err = forward_route_serial(&m, &names(&["a", "c"]), None, &x).unwrap_err();
        assert!(format!("{err}").contains("route break"), "{err}");
        let err = forward_route_serial(&m, &names(&["a", "nope"]), None, &x).unwrap_err();
        assert!(format!("{err}").contains("'nope'"), "{err}");
    }

    #[test]
    fn traversal_walks_route_then_replies() {
        let (tx, rx) = mpsc::channel();
        let route = Arc::new(vec![0usize, 1, 2]);
        let t0 = Instant::now();
        let mut tr = Box::new(Traversal::new(route, 1, None, tx, t0));
        let rows_of = |_: usize| 4usize;
        for expect_layer in [1usize, 2] {
            match tr.absorb_hop(vec![0.0; 4], 1e-6, 2e-6, 3, 1, &rows_of) {
                HopOutcome::Reenter { layer, traversal, .. } => {
                    assert_eq!(layer, expect_layer);
                    tr = traversal;
                }
                HopOutcome::Replied { .. } => panic!("route not exhausted yet"),
            }
        }
        match tr.absorb_hop(vec![7.0; 4], 1e-6, 2e-6, 5, 2, &rows_of) {
            HopOutcome::Replied { ok, forwards } => {
                assert!(ok);
                assert_eq!(forwards, 1);
            }
            HopOutcome::Reenter { .. } => panic!("route exhausted"),
        }
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.y, vec![7.0; 4]);
        assert_eq!(resp.hops, 3);
        assert_eq!(resp.forwards, 1);
        assert_eq!(resp.max_batch_seen, 5);
        assert_eq!(resp.mixed_hops, 1);
        assert!((resp.queue_s - 3e-6).abs() < 1e-12);
    }

    #[test]
    fn session_step_bridges_forwards_and_can_stop_early() {
        let (tx, rx) = mpsc::channel();
        let route = Arc::new(vec![0usize]);
        let step: StepFn =
            Box::new(|k, y| if k < 2 { Some(y.iter().map(|v| v + 1.0).collect()) } else { None });
        let mut tr =
            Box::new(Traversal::new(route, 10, Some(step), tx, Instant::now()));
        let rows_of = |_: usize| 2usize;
        // Forward 1 done → step runs → re-enter at the route head.
        tr = match tr.absorb_hop(vec![1.0, 1.0], 0.0, 0.0, 1, 1, &rows_of) {
            HopOutcome::Reenter { layer, x, traversal } => {
                assert_eq!(layer, 0);
                assert_eq!(x, vec![2.0, 2.0]);
                traversal
            }
            _ => panic!("step must continue the session"),
        };
        // Forward 2 done → step returns None → early stop at forwards=2.
        match tr.absorb_hop(vec![5.0, 5.0], 0.0, 0.0, 1, 1, &rows_of) {
            HopOutcome::Replied { ok, forwards } => {
                assert!(ok);
                assert_eq!(forwards, 2);
            }
            _ => panic!("step returned None: session must end"),
        }
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.forwards, 2);
        assert_eq!(resp.hops, 2);
        assert_eq!(resp.y, vec![5.0, 5.0]);
    }

    #[test]
    fn misshapen_step_output_fails_the_session_actionably() {
        let (tx, rx) = mpsc::channel();
        let step: StepFn = Box::new(|_, _| Some(vec![0.0; 99]));
        let tr = Box::new(Traversal::new(
            Arc::new(vec![0usize]),
            3,
            Some(step),
            tx,
            Instant::now(),
        ));
        match tr.absorb_hop(vec![0.0; 2], 0.0, 0.0, 1, 1, &|_| 2usize) {
            HopOutcome::Replied { ok, forwards } => {
                assert!(!ok);
                assert_eq!(forwards, 1);
            }
            _ => panic!("bad step output must fail the session"),
        }
        let err = rx.recv().unwrap().unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("99 values"), "{msg}");
        assert!(msg.contains("takes 2 features"), "{msg}");
    }

    #[test]
    fn panicking_step_fails_only_its_session() {
        let (tx, rx) = mpsc::channel();
        let step: StepFn = Box::new(|_, _| panic!("injected step panic"));
        let tr = Box::new(Traversal::new(
            Arc::new(vec![0usize]),
            2,
            Some(step),
            tx,
            Instant::now(),
        ));
        match tr.absorb_hop(vec![0.0; 2], 0.0, 0.0, 1, 1, &|_| 2usize) {
            HopOutcome::Replied { ok, .. } => assert!(!ok),
            _ => panic!("step panic must fail the session"),
        }
        let err = rx.recv().unwrap().unwrap_err();
        assert!(format!("{err}").contains("step function panicked"), "{err}");
    }
}

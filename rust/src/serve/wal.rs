//! Crash-safe adapter-registry write-ahead log.
//!
//! The engine's [`AdapterRegistry`](crate::serve::adapters::AdapterRegistry)
//! is in-memory state: without a log, every tenant's adapters die with
//! the process. [`Wal`] makes registration durable with the classic
//! log-structured recipe: an append-only file of register / hot-swap /
//! unregister events, fsync-batched, replayed on boot, and compacted
//! down to the live set once the log dwarfs it.
//!
//! ```text
//!   header   magic "CLOQWAL1" (8) · version u32 (= 1)
//!   records  len u32 · payload · crc32(payload) u32
//!   payload  op u8 (1 = register/hot-swap, 2 = unregister) · body
//!     register body    id str · n_layers u32 · per layer:
//!                      blob_len u32 · adapter blob (the CLOQADP1 layer
//!                      payload encoding: name, shapes, rank, A, B)
//!     unregister body  id str
//! ```
//!
//! **Recovery contract** (locked by `rust/tests/crash_wal.rs`): however
//! many bytes of the log survive a crash, [`Wal::open`] recovers exactly
//! a PREFIX of the committed operations — the record framing (length up
//! front, CRC behind) makes every torn or half-written tail detectable,
//! and parsing stops at the first incomplete or checksum-failing record.
//! A torn tail is then REPAIRED by compacting the recovered state back
//! to disk, so the next append never lands after garbage. A record whose
//! CRC passes but whose payload does not decode is NOT a torn write —
//! it's corruption or a format bug — and fails loudly with a typed
//! `Malformed` error instead of silently truncating history.
//!
//! **Group commit**: append and fsync are split ([`Wal::append_register`]
//! / [`Wal::append_unregister`] return a sequence number;
//! [`Wal::commit_through`] makes everything up to it durable). One fsync
//! advances the durable watermark over ALL appended operations, so N
//! threads registering concurrently share one fsync instead of paying N
//! — the engine acks each caller only after its commit returns, so
//! acknowledged ⇒ durable still holds. [`Wal::log_register`] /
//! [`Wal::log_unregister`] fuse the two for serial callers.
//!
//! **Compaction snapshots** ([`Wal::open_snapshotted`]): with a snapshot
//! file attached, compaction no longer rewrites history into the log —
//! it writes the live state into a checksummed `CLOQSNP1` snapshot
//! (same record framing, register payloads only) and truncates the log
//! to its header. Boot then loads the snapshot and replays only the
//! records appended SINCE it, so recovery is O(live + tail), not
//! O(history). The write order is the crash-safety argument: snapshot
//! first (atomic replace), log truncation second. A crash between the
//! two leaves the new snapshot plus the full old log, and replaying
//! both — snapshot registers, then the log's history — converges to the
//! same live state, because register replay is a hot-swap and
//! unregister replay is idempotent. Each snapshot write ticks the
//! `WalSnapshots` counter.
//!
//! All I/O goes through the [`WalFile`] trait so the fault-injection
//! suite can kill the "process" at any byte; [`FsWalFile`] is the real
//! filesystem implementation (`O_APPEND` writes, `fdatasync` batching,
//! write-temp-then-rename compaction).

use std::collections::BTreeMap;
use std::io;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use crate::serve::adapters::AdapterSet;
use crate::serve::artifact::{
    crc32, decode_layer_adapter, encode_layer_adapter, put_str, put_u32, Rd,
};
use crate::serve::error::{ArtifactErrorKind, ServeError};
use crate::serve::telemetry::{Counter, Metric, Telemetry};

/// WAL file magic + version.
pub const MAGIC_WAL: &[u8; 8] = b"CLOQWAL1";
pub const VERSION_WAL: u32 = 1;

/// Compaction-snapshot file magic + version.
pub const MAGIC_SNAP: &[u8; 8] = b"CLOQSNP1";
pub const VERSION_SNAP: u32 = 1;

/// The complete 12-byte header a healthy WAL starts with.
fn wal_header() -> [u8; 12] {
    let mut h = [0u8; 12];
    h[..8].copy_from_slice(MAGIC_WAL);
    h[8..].copy_from_slice(&VERSION_WAL.to_le_bytes());
    h
}

/// The 12-byte header a snapshot file starts with.
fn snap_header() -> [u8; 12] {
    let mut h = [0u8; 12];
    h[..8].copy_from_slice(MAGIC_SNAP);
    h[8..].copy_from_slice(&VERSION_SNAP.to_le_bytes());
    h
}

const OP_REGISTER: u8 = 1;
const OP_UNREGISTER: u8 = 2;

/// Framed record overhead: length prefix (u32) + trailing CRC (u32).
const FRAME_BYTES: usize = 8;

/// The WAL's I/O surface. Production uses [`FsWalFile`]; the crash suite
/// injects implementations that truncate, tear, or duplicate at
/// arbitrary byte offsets — everything [`Wal`] does to disk goes through
/// these four calls, so a test can kill the "process" at any byte.
pub trait WalFile: Send {
    /// The file's current bytes (empty when it does not exist yet).
    fn read_all(&mut self) -> io::Result<Vec<u8>>;
    /// Append bytes at the end.
    fn append(&mut self, bytes: &[u8]) -> io::Result<()>;
    /// Make appended bytes durable (fsync).
    fn sync(&mut self) -> io::Result<()>;
    /// Atomically replace the whole file (compaction / torn-tail repair).
    /// Must be durable on return.
    fn replace(&mut self, bytes: &[u8]) -> io::Result<()>;
}

/// Filesystem-backed [`WalFile`]: append-mode writes, `fdatasync` on
/// [`WalFile::sync`], and write-temp + fsync + rename on
/// [`WalFile::replace`] so a crash mid-compaction leaves either the old
/// or the new log, never a mix.
pub struct FsWalFile {
    path: PathBuf,
    file: Option<std::fs::File>,
}

impl FsWalFile {
    pub fn at(path: impl Into<PathBuf>) -> FsWalFile {
        FsWalFile { path: path.into(), file: None }
    }

    fn handle(&mut self) -> io::Result<&mut std::fs::File> {
        if self.file.is_none() {
            if let Some(parent) = self.path.parent() {
                std::fs::create_dir_all(parent)?;
            }
            self.file =
                Some(std::fs::OpenOptions::new().create(true).append(true).open(&self.path)?);
        }
        Ok(self.file.as_mut().unwrap())
    }
}

impl WalFile for FsWalFile {
    fn read_all(&mut self) -> io::Result<Vec<u8>> {
        match std::fs::read(&self.path) {
            Ok(bytes) => Ok(bytes),
            Err(e) if e.kind() == io::ErrorKind::NotFound => Ok(Vec::new()),
            Err(e) => Err(e),
        }
    }

    fn append(&mut self, bytes: &[u8]) -> io::Result<()> {
        use std::io::Write;
        self.handle()?.write_all(bytes)
    }

    fn sync(&mut self) -> io::Result<()> {
        self.handle()?.sync_data()
    }

    fn replace(&mut self, bytes: &[u8]) -> io::Result<()> {
        use std::io::Write;
        // Drop the append handle first: after the rename it would point
        // at the unlinked old inode.
        self.file = None;
        if let Some(parent) = self.path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let tmp = self.path.with_extension("wal.tmp");
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(bytes)?;
        f.sync_all()?;
        drop(f);
        std::fs::rename(&tmp, &self.path)
    }
}

/// One replayed operation, in committed order. Registers carry the full
/// decoded set (hot-swaps replay as a second register of the same id);
/// the engine applies them through the normal registry path on boot.
pub enum WalEvent {
    Register(AdapterSet),
    Unregister(String),
}

/// Tuning knobs for fsync batching and compaction.
#[derive(Clone, Copy, Debug)]
pub struct WalOptions {
    /// fsync after every N logged operations (1 = every op durable before
    /// the in-memory state changes — the default; raise it to trade the
    /// tail of a crash for throughput).
    pub sync_every: usize,
    /// Never compact below this log size (compaction rewrites the whole
    /// live state; pointless for tiny logs).
    pub compact_min_bytes: usize,
    /// Compact when the log exceeds `ratio ×` the live state's size.
    pub compact_ratio: usize,
}

impl Default for WalOptions {
    fn default() -> WalOptions {
        WalOptions { sync_every: 1, compact_min_bytes: 64 * 1024, compact_ratio: 4 }
    }
}

/// The adapter write-ahead log: replay on open, append-per-operation,
/// compaction once live state ≪ log size. See the module docs for the
/// format and the recovery contract.
pub struct Wal {
    file: Box<dyn WalFile>,
    /// Compaction-snapshot backing, when attached
    /// ([`Wal::open_snapshotted`]): compaction writes live state here
    /// and truncates the log instead of rewriting history into it.
    snap: Option<Box<dyn WalFile>>,
    /// Human-readable log identity for typed errors (a path, usually).
    label: String,
    opts: WalOptions,
    /// Live state: adapter-set id → its latest register record PAYLOAD
    /// (compaction re-frames these; deterministic BTreeMap order).
    live: BTreeMap<String, Vec<u8>>,
    /// Current log size in bytes (header + every framed record).
    log_bytes: usize,
    /// Operations appended since the last fsync.
    unsynced: usize,
    /// Sequence number of the last appended operation (1-based).
    ops_appended: u64,
    /// High-water mark of appended operations known durable (covered by
    /// an fsync or a compaction replace). `commit_through` compares
    /// against this so concurrent committers share one fsync.
    ops_durable: u64,
    /// Engine telemetry, when attached: append/fsync/compaction counters
    /// plus the fsync-duration histogram.
    telemetry: Option<Arc<Telemetry>>,
}

impl Wal {
    /// Open (or create) a log and replay it. Returns the WAL plus the
    /// recovered events in committed order — exactly a prefix of the
    /// operations ever logged, per the recovery contract. A torn tail is
    /// repaired (compacted) before this returns, so subsequent appends
    /// land after valid bytes.
    pub fn open(
        file: Box<dyn WalFile>,
        label: &str,
        opts: WalOptions,
    ) -> Result<(Wal, Vec<WalEvent>), ServeError> {
        Self::open_inner(file, None, label, opts)
    }

    /// [`Wal::open`] with a compaction-snapshot file attached: the
    /// snapshot's live state replays first (as register events, in id
    /// order), then the log's records on top of it. Compaction from now
    /// on writes the snapshot and truncates the log, so boot replay
    /// stays O(live + tail) however much the registry churns. See the
    /// module docs for the crash-ordering argument.
    pub fn open_snapshotted(
        file: Box<dyn WalFile>,
        snap: Box<dyn WalFile>,
        label: &str,
        opts: WalOptions,
    ) -> Result<(Wal, Vec<WalEvent>), ServeError> {
        Self::open_inner(file, Some(snap), label, opts)
    }

    fn open_inner(
        mut file: Box<dyn WalFile>,
        mut snap: Option<Box<dyn WalFile>>,
        label: &str,
        opts: WalOptions,
    ) -> Result<(Wal, Vec<WalEvent>), ServeError> {
        let err = |kind: ArtifactErrorKind, detail: String| ServeError::Artifact {
            path: label.to_string(),
            layer: None,
            kind,
            detail,
        };
        let io_err = |what: &str, e: io::Error| {
            err(ArtifactErrorKind::Io, format!("{what}: {e}"))
        };
        // The snapshot replays FIRST: it is the state every surviving log
        // record was appended against.
        let (seed_live, seed_events) = match &mut snap {
            Some(s) => read_snapshot(s.as_mut(), label)?,
            None => (BTreeMap::new(), Vec::new()),
        };
        let bytes = file.read_all().map_err(|e| io_err("cannot read", e))?;
        let header = wal_header();
        if bytes.len() < header.len() {
            // Fresh log, or a crash tore the header write itself: both
            // recover to the empty state. Anything that is NOT a prefix
            // of the correct header is some other file — refuse it
            // rather than overwrite it.
            if !header.starts_with(&bytes) {
                return Err(if bytes.len() >= 8 && bytes[..8] == MAGIC_WAL[..] {
                    err(
                        ArtifactErrorKind::BadVersion,
                        "unsupported WAL version bytes (torn from a different build?)"
                            .to_string(),
                    )
                } else {
                    err(
                        ArtifactErrorKind::BadMagic,
                        format!("not a CLOQWAL1 write-ahead log ({} bytes)", bytes.len()),
                    )
                });
            }
            let mut wal = Wal {
                file,
                snap,
                label: label.to_string(),
                opts,
                live: seed_live,
                log_bytes: 0,
                unsynced: 0,
                ops_appended: 0,
                ops_durable: 0,
                telemetry: None,
            };
            wal.compact().map_err(|e| io_err("cannot initialize", e))?;
            return Ok((wal, seed_events));
        }
        if bytes[..8] != MAGIC_WAL[..] {
            return Err(err(
                ArtifactErrorKind::BadMagic,
                format!("bad magic {:02x?} (expected {MAGIC_WAL:02x?})", &bytes[..8]),
            ));
        }
        let version = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
        if version != VERSION_WAL {
            return Err(err(
                ArtifactErrorKind::BadVersion,
                format!("unsupported WAL version {version} (this build reads {VERSION_WAL})"),
            ));
        }

        // Record loop: stop at the FIRST incomplete or CRC-failing
        // record — everything before it is the recovered prefix,
        // everything from it on is a torn tail to discard.
        let mut events = seed_events;
        let mut live: BTreeMap<String, Vec<u8>> = seed_live;
        let mut off = header.len();
        let mut torn = false;
        while off < bytes.len() {
            let rest = &bytes[off..];
            if rest.len() < 4 {
                torn = true;
                break;
            }
            let len = u32::from_le_bytes(rest[..4].try_into().unwrap()) as usize;
            if rest.len() < 4 + len + 4 {
                torn = true;
                break;
            }
            let payload = &rest[4..4 + len];
            let stored = u32::from_le_bytes(rest[4 + len..4 + len + 4].try_into().unwrap());
            if crc32(payload) != stored {
                torn = true;
                break;
            }
            // The CRC passed: an undecodable payload is corruption with
            // a valid checksum (or a writer bug) — typed failure, not
            // silent truncation.
            let idx = events.len();
            match decode_record(payload).map_err(|e| {
                err(ArtifactErrorKind::Malformed, format!("record {idx}: {e}"))
            })? {
                WalEvent::Register(set) => {
                    live.insert(set.id().to_string(), payload.to_vec());
                    events.push(WalEvent::Register(set));
                }
                WalEvent::Unregister(id) => {
                    // An unregister whose id never registered cannot
                    // arise from this writer; dropped defensively so
                    // replay stays idempotent.
                    if live.remove(&id).is_some() {
                        events.push(WalEvent::Unregister(id));
                    }
                }
            }
            off += 4 + len + 4;
        }
        let mut wal = Wal {
            file,
            snap,
            label: label.to_string(),
            opts,
            live,
            log_bytes: off,
            unsynced: 0,
            ops_appended: 0,
            ops_durable: 0,
            telemetry: None,
        };
        if torn {
            // Repair: rewrite header + live records so the next append
            // never lands after garbage. The recovered events are
            // untouched — repair changes bytes on disk, not history.
            wal.compact().map_err(|e| io_err("cannot repair torn tail", e))?;
        }
        Ok((wal, events))
    }

    /// Attach engine telemetry: appends, fsync batches (count + duration
    /// histogram), and compactions become observable.
    pub fn attach_telemetry(&mut self, telemetry: Arc<Telemetry>) {
        self.telemetry = Some(telemetry);
    }

    /// Log a register (or hot-swap — same op, the id decides) and commit
    /// it: append → fsync batch; callers apply the operation to the
    /// in-memory registry only AFTER this returns, so the log is always
    /// ahead of the state it protects. Equivalent to
    /// [`Wal::append_register`] + [`Wal::commit_through`] under one lock
    /// — the engine splits the two to group-commit concurrent callers.
    pub fn log_register(&mut self, set: &AdapterSet) -> Result<(), ServeError> {
        let seq = self.append_register(set)?;
        self.commit_through(seq)
    }

    /// Log an unregister and commit it. The id must be live (the engine
    /// checks before logging).
    pub fn log_unregister(&mut self, id: &str) -> Result<(), ServeError> {
        let seq = self.append_unregister(id)?;
        self.commit_through(seq)
    }

    /// Append a register record WITHOUT forcing it durable; returns its
    /// sequence number for [`Wal::commit_through`]. The caller must not
    /// acknowledge the operation until the commit returns.
    pub fn append_register(&mut self, set: &AdapterSet) -> Result<u64, ServeError> {
        let payload = encode_register(set);
        self.append_op(payload, |live, p| {
            live.insert(set.id().to_string(), p);
        })
    }

    /// Append an unregister record WITHOUT forcing it durable; returns
    /// its sequence number for [`Wal::commit_through`].
    pub fn append_unregister(&mut self, id: &str) -> Result<u64, ServeError> {
        let mut payload = vec![OP_UNREGISTER];
        put_str(&mut payload, id);
        let id = id.to_string();
        self.append_op(payload, move |live, _| {
            live.remove(&id);
        })
    }

    /// Make every operation up to `seq` durable under the configured
    /// fsync-batching policy. GROUP COMMIT: one fsync advances the
    /// durable watermark over ALL appended operations, so when N threads
    /// append and then race here, the first to arrive pays the fsync and
    /// the other N−1 return immediately — the fsync-per-op cost under
    /// concurrent registration drops toward 1/N (observable in the
    /// `WalFsyncs` counter and the fsync-duration histogram;
    /// before/after in `BENCH_artifact.json`'s group_commit rows).
    ///
    /// With `sync_every > 1` the batching policy still applies: the
    /// operation may be left unsynced (the configured durability
    /// relaxation, exactly as the fused log-path behaved).
    pub fn commit_through(&mut self, seq: u64) -> Result<(), ServeError> {
        if self.ops_durable >= seq || self.unsynced < self.opts.sync_every {
            return Ok(());
        }
        self.sync_now()
    }

    /// Current log size in bytes (diagnostics + the bench harness).
    pub fn log_bytes(&self) -> usize {
        self.log_bytes
    }

    /// Number of live adapter sets in the log's state.
    pub fn live_len(&self) -> usize {
        self.live.len()
    }

    fn io_err(&self, what: &str, e: io::Error) -> ServeError {
        ServeError::Artifact {
            path: self.label.clone(),
            layer: None,
            kind: ArtifactErrorKind::Io,
            detail: format!("{what}: {e}"),
        }
    }

    fn append_op(
        &mut self,
        payload: Vec<u8>,
        apply: impl FnOnce(&mut BTreeMap<String, Vec<u8>>, Vec<u8>),
    ) -> Result<u64, ServeError> {
        let framed = frame(&payload);
        self.file.append(&framed).map_err(|e| self.io_err("cannot append", e))?;
        self.ops_appended += 1;
        self.unsynced += 1;
        self.log_bytes += framed.len();
        if let Some(t) = &self.telemetry {
            t.incr(Counter::WalAppends);
        }
        apply(&mut self.live, payload);
        // Compaction may trigger here; `replace` is durable on return, so
        // it counts as the commit for everything appended so far and the
        // racing `commit_through` calls become no-ops.
        self.maybe_compact()?;
        Ok(self.ops_appended)
    }

    /// fsync now, whatever the batching policy says, and advance the
    /// durable watermark over everything appended.
    fn sync_now(&mut self) -> Result<(), ServeError> {
        let t0 = Instant::now();
        self.file.sync().map_err(|e| self.io_err("cannot sync", e))?;
        self.unsynced = 0;
        self.ops_durable = self.ops_appended;
        if let Some(t) = &self.telemetry {
            t.incr(Counter::WalFsyncs);
            t.observe(Metric::WalFsync, t0.elapsed().as_secs_f64());
        }
        Ok(())
    }

    /// Bytes of a compacted log holding the current live state.
    fn live_bytes(&self) -> usize {
        wal_header().len()
            + self.live.values().map(|p| p.len() + FRAME_BYTES).sum::<usize>()
    }

    fn maybe_compact(&mut self) -> Result<(), ServeError> {
        if self.log_bytes >= self.opts.compact_min_bytes
            && self.log_bytes > self.opts.compact_ratio * self.live_bytes()
        {
            self.compact().map_err(|e| self.io_err("cannot compact", e))?;
        }
        Ok(())
    }

    /// Compact the log down to the live state. Without a snapshot file:
    /// rewrite the log as header + one register record per live set
    /// (deterministic id order). With one: write the live set into the
    /// snapshot (same framing, `CLOQSNP1` header) and truncate the log
    /// to its header — snapshot FIRST, so a crash between the two
    /// replaces leaves new-snapshot + old-full-log, which replays to the
    /// same state. Used for routine compaction AND torn-tail repair;
    /// `WalFile::replace` guarantees old-or-new, never a mix.
    fn compact(&mut self) -> io::Result<()> {
        let buf = match &mut self.snap {
            Some(snap) => {
                let mut sbuf = snap_header().to_vec();
                for payload in self.live.values() {
                    sbuf.extend_from_slice(&frame(payload));
                }
                snap.replace(&sbuf)?;
                if let Some(t) = &self.telemetry {
                    t.incr(Counter::WalSnapshots);
                }
                wal_header().to_vec()
            }
            None => {
                let mut buf = wal_header().to_vec();
                for payload in self.live.values() {
                    buf.extend_from_slice(&frame(payload));
                }
                buf
            }
        };
        self.file.replace(&buf)?;
        self.log_bytes = buf.len();
        self.unsynced = 0;
        // `replace` is durable on return: every appended op is now
        // either in the snapshot/new log's live state or superseded by it.
        self.ops_durable = self.ops_appended;
        if let Some(t) = &self.telemetry {
            t.incr(Counter::WalCompactions);
        }
        Ok(())
    }
}

/// Load a compaction snapshot: live payloads keyed by id plus the
/// register events to replay (id order — the order the payloads sit in
/// the file). Unlike the log, a snapshot is written in ONE atomic
/// replace, so a half-record or CRC failure cannot be a torn tail — it
/// is corruption, and fails loudly instead of being truncated away.
fn read_snapshot(
    snap: &mut dyn WalFile,
    label: &str,
) -> Result<(BTreeMap<String, Vec<u8>>, Vec<WalEvent>), ServeError> {
    let err = |kind: ArtifactErrorKind, detail: String| ServeError::Artifact {
        path: format!("{label} (snapshot)"),
        layer: None,
        kind,
        detail,
    };
    let bytes = snap
        .read_all()
        .map_err(|e| err(ArtifactErrorKind::Io, format!("cannot read: {e}")))?;
    let header = snap_header();
    if bytes.is_empty() {
        // No snapshot yet: every compaction so far ran without one.
        return Ok((BTreeMap::new(), Vec::new()));
    }
    if bytes.len() < header.len() || bytes[..8] != MAGIC_SNAP[..] {
        return Err(err(
            ArtifactErrorKind::BadMagic,
            format!("not a CLOQSNP1 compaction snapshot ({} bytes)", bytes.len()),
        ));
    }
    let version = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
    if version != VERSION_SNAP {
        return Err(err(
            ArtifactErrorKind::BadVersion,
            format!("unsupported snapshot version {version} (this build reads {VERSION_SNAP})"),
        ));
    }
    let mut live = BTreeMap::new();
    let mut events = Vec::new();
    let mut off = header.len();
    while off < bytes.len() {
        let rest = &bytes[off..];
        if rest.len() < 4 {
            return Err(err(
                ArtifactErrorKind::Malformed,
                "truncated record length in an atomically-written snapshot".to_string(),
            ));
        }
        let len = u32::from_le_bytes(rest[..4].try_into().unwrap()) as usize;
        if rest.len() < 4 + len + 4 {
            return Err(err(
                ArtifactErrorKind::Malformed,
                format!("record at byte {off} overruns the snapshot"),
            ));
        }
        let payload = &rest[4..4 + len];
        let stored = u32::from_le_bytes(rest[4 + len..4 + len + 4].try_into().unwrap());
        if crc32(payload) != stored {
            return Err(err(
                ArtifactErrorKind::Malformed,
                format!("checksum mismatch at byte {off}"),
            ));
        }
        let idx = events.len();
        match decode_record(payload)
            .map_err(|e| err(ArtifactErrorKind::Malformed, format!("record {idx}: {e}")))?
        {
            WalEvent::Register(set) => {
                live.insert(set.id().to_string(), payload.to_vec());
                events.push(WalEvent::Register(set));
            }
            WalEvent::Unregister(id) => {
                return Err(err(
                    ArtifactErrorKind::Malformed,
                    format!("snapshot holds an unregister record for '{id}'; snapshots are \
                             live state only"),
                ));
            }
        }
        off += 4 + len + 4;
    }
    Ok((live, events))
}

/// Frame a payload: `len u32 · payload · crc32 u32`.
fn frame(payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(payload.len() + FRAME_BYTES);
    put_u32(&mut out, payload.len() as u32);
    out.extend_from_slice(payload);
    put_u32(&mut out, crc32(payload));
    out
}

fn encode_register(set: &AdapterSet) -> Vec<u8> {
    let mut b = vec![OP_REGISTER];
    put_str(&mut b, set.id());
    put_u32(&mut b, set.len() as u32);
    for (name, pair) in set.entries() {
        let blob = encode_layer_adapter(name, pair);
        put_u32(&mut b, blob.len() as u32);
        b.extend_from_slice(&blob);
    }
    b
}

fn decode_record(payload: &[u8]) -> anyhow::Result<WalEvent> {
    let mut rd = Rd::new(payload);
    let op = rd.bytes(1, "op byte")?[0];
    match op {
        OP_REGISTER => {
            let id = rd.str("adapter-set id")?;
            let n = rd.u32("layer count")? as usize;
            let mut set = AdapterSet::new(&id);
            for i in 0..n {
                let blob_len = rd.u32(&format!("layer {i} blob length"))? as usize;
                let blob = rd.bytes(blob_len, &format!("layer {i} blob"))?;
                let (name, pair) = decode_layer_adapter(blob)?;
                set.insert(&name, pair)?;
            }
            anyhow::ensure!(
                rd.remaining() == 0,
                "{} trailing bytes after register body",
                rd.remaining()
            );
            Ok(WalEvent::Register(set))
        }
        OP_UNREGISTER => {
            let id = rd.str("adapter-set id")?;
            anyhow::ensure!(
                rd.remaining() == 0,
                "{} trailing bytes after unregister body",
                rd.remaining()
            );
            Ok(WalEvent::Unregister(id))
        }
        other => anyhow::bail!("unknown op byte {other}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::lowrank::LoraPair;
    use crate::util::prng::Rng;

    /// In-memory WalFile for unit tests (the crash suite injects its own
    /// failing variants through the same trait).
    struct MemWalFile {
        bytes: Vec<u8>,
    }

    impl WalFile for MemWalFile {
        fn read_all(&mut self) -> io::Result<Vec<u8>> {
            Ok(self.bytes.clone())
        }
        fn append(&mut self, bytes: &[u8]) -> io::Result<()> {
            self.bytes.extend_from_slice(bytes);
            Ok(())
        }
        fn sync(&mut self) -> io::Result<()> {
            Ok(())
        }
        fn replace(&mut self, bytes: &[u8]) -> io::Result<()> {
            self.bytes = bytes.to_vec();
            Ok(())
        }
    }

    fn mk_set(id: &str, seed: u64) -> AdapterSet {
        let mut rng = Rng::new(seed);
        AdapterSet::from_pairs(
            id,
            vec![(
                "l0".to_string(),
                LoraPair::new(
                    Matrix::randn(6, 2, 0.1, &mut rng),
                    Matrix::randn(4, 2, 0.1, &mut rng),
                ),
            )],
        )
        .unwrap()
    }

    #[test]
    fn fresh_log_roundtrips_register_and_unregister() {
        let dir = std::env::temp_dir().join(format!("cloq_wal_{}", std::process::id()));
        let path = dir.join("adapters.wal");
        {
            let (mut wal, events) =
                Wal::open(Box::new(FsWalFile::at(&path)), "t", WalOptions::default()).unwrap();
            assert!(events.is_empty());
            wal.log_register(&mk_set("a", 1)).unwrap();
            wal.log_register(&mk_set("b", 2)).unwrap();
            wal.log_unregister("a").unwrap();
        }
        let (wal, events) =
            Wal::open(Box::new(FsWalFile::at(&path)), "t", WalOptions::default()).unwrap();
        assert_eq!(wal.live_len(), 1);
        let kinds: Vec<String> = events
            .iter()
            .map(|e| match e {
                WalEvent::Register(s) => format!("+{}", s.id()),
                WalEvent::Unregister(id) => format!("-{id}"),
            })
            .collect();
        assert_eq!(kinds, ["+a", "+b", "-a"]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn compaction_shrinks_a_churned_log_and_preserves_state() {
        let file = MemWalFile { bytes: Vec::new() };
        let opts = WalOptions { sync_every: 1, compact_min_bytes: 1024, compact_ratio: 2 };
        let (mut wal, _) = Wal::open(Box::new(file), "mem", opts).unwrap();
        for round in 0..50u64 {
            wal.log_register(&mk_set("hot", round)).unwrap(); // 49 hot-swaps
        }
        // Compaction kicked in: the log holds ~one live record, not 50.
        assert_eq!(wal.live_len(), 1);
        assert!(
            wal.log_bytes() < 3 * wal.live_bytes(),
            "log {} vs live {}",
            wal.log_bytes(),
            wal.live_bytes()
        );
    }

    /// Clonable storage so one test can reopen the same "disk" bytes —
    /// the snapshot suite's stand-in for a restart.
    #[derive(Clone)]
    struct SharedMemFile {
        bytes: Arc<std::sync::Mutex<Vec<u8>>>,
    }

    impl SharedMemFile {
        fn new() -> SharedMemFile {
            SharedMemFile { bytes: Arc::new(std::sync::Mutex::new(Vec::new())) }
        }
        fn raw(&self) -> Vec<u8> {
            self.bytes.lock().unwrap().clone()
        }
    }

    impl WalFile for SharedMemFile {
        fn read_all(&mut self) -> io::Result<Vec<u8>> {
            Ok(self.raw())
        }
        fn append(&mut self, bytes: &[u8]) -> io::Result<()> {
            self.bytes.lock().unwrap().extend_from_slice(bytes);
            Ok(())
        }
        fn sync(&mut self) -> io::Result<()> {
            Ok(())
        }
        fn replace(&mut self, bytes: &[u8]) -> io::Result<()> {
            *self.bytes.lock().unwrap() = bytes.to_vec();
            Ok(())
        }
    }

    /// SharedMemFile whose `replace` can be made to fail on demand — the
    /// "process dies between the snapshot write and the log truncation"
    /// injection point.
    #[derive(Clone)]
    struct FailSwitchFile {
        inner: SharedMemFile,
        fail_replace: Arc<std::sync::atomic::AtomicBool>,
    }

    impl FailSwitchFile {
        fn new() -> FailSwitchFile {
            FailSwitchFile {
                inner: SharedMemFile::new(),
                fail_replace: Arc::new(std::sync::atomic::AtomicBool::new(false)),
            }
        }
    }

    impl WalFile for FailSwitchFile {
        fn read_all(&mut self) -> io::Result<Vec<u8>> {
            self.inner.read_all()
        }
        fn append(&mut self, bytes: &[u8]) -> io::Result<()> {
            self.inner.append(bytes)
        }
        fn sync(&mut self) -> io::Result<()> {
            self.inner.sync()
        }
        fn replace(&mut self, bytes: &[u8]) -> io::Result<()> {
            if self.fail_replace.load(std::sync::atomic::Ordering::SeqCst) {
                return Err(io::Error::other("injected crash before log truncation"));
            }
            self.inner.replace(bytes)
        }
    }

    fn open_snap(
        log: &SharedMemFile,
        snap: &SharedMemFile,
        opts: WalOptions,
    ) -> (Wal, Vec<WalEvent>) {
        Wal::open_snapshotted(Box::new(log.clone()), Box::new(snap.clone()), "mem", opts)
            .unwrap()
    }

    #[test]
    fn snapshot_compaction_keeps_boot_replay_o_live() {
        let log = SharedMemFile::new();
        let snap = SharedMemFile::new();
        let opts = WalOptions { sync_every: 1, compact_min_bytes: 256, compact_ratio: 2 };
        {
            let (mut wal, events) = open_snap(&log, &snap, opts);
            assert!(events.is_empty());
            wal.log_register(&mk_set("a", 1)).unwrap();
            for round in 0..50u64 {
                wal.log_register(&mk_set("hot", round)).unwrap();
            }
            wal.log_unregister("a").unwrap();
            assert_eq!(wal.live_len(), 1);
        }
        assert!(snap.raw().len() > 12, "compaction never wrote a snapshot");
        // Restart: the 51-op history replays as snapshot live-state plus
        // the short tail since the last compaction, not op by op.
        let (wal, events) = open_snap(&log, &snap, opts);
        assert_eq!(wal.live_len(), 1);
        assert!(events.len() < 20, "O(history) replay: {} events for 1 live set", events.len());
        assert!(
            log.raw().len() < snap.raw().len(),
            "log ({} bytes) should be a tail, snapshot ({} bytes) the state",
            log.raw().len(),
            snap.raw().len()
        );
    }

    #[test]
    fn crash_between_snapshot_write_and_log_truncation_converges() {
        let log = FailSwitchFile::new();
        let snap = SharedMemFile::new();
        let opts = WalOptions { sync_every: 1, compact_min_bytes: 256, compact_ratio: 1 };
        let (mut wal, _) = Wal::open_snapshotted(
            Box::new(log.clone()),
            Box::new(snap.clone()),
            "mem",
            opts,
        )
        .unwrap();
        wal.log_register(&mk_set("a", 1)).unwrap();
        wal.log_register(&mk_set("b", 2)).unwrap();
        // From here on the log's `replace` dies, so the next compaction
        // writes the snapshot and then "crashes" before truncating.
        log.fail_replace.store(true, std::sync::atomic::Ordering::SeqCst);
        let mut crashed = false;
        for round in 0..200u64 {
            if wal.log_register(&mk_set("hot", round)).is_err() {
                crashed = true;
                break;
            }
        }
        assert!(crashed, "compaction never triggered under churn");
        let expected = wal.live.clone();
        drop(wal);
        log.fail_replace.store(false, std::sync::atomic::Ordering::SeqCst);
        // Disk state: NEW snapshot + FULL old log. Replaying both must
        // converge to the pre-crash live state (registers hot-swap,
        // unregisters are idempotent).
        assert!(snap.raw().len() > 12, "snapshot must be durable before the crash point");
        let recovered = Wal::open_snapshotted(
            Box::new(log.clone()),
            Box::new(snap.clone()),
            "mem",
            opts,
        )
        .unwrap()
        .0;
        assert_eq!(recovered.live, expected, "snapshot+old-log replay diverged");
    }

    #[test]
    fn corrupt_snapshot_is_refused_loudly() {
        // Wrong magic: some other file is sitting at the snapshot path.
        let snap = SharedMemFile::new();
        *snap.bytes.lock().unwrap() = b"CLOQWAL1\x01\x00\x00\x00".to_vec();
        let err = Wal::open_snapshotted(
            Box::new(SharedMemFile::new()),
            Box::new(snap),
            "mem",
            WalOptions::default(),
        )
        .unwrap_err();
        assert!(
            matches!(&err, ServeError::Artifact { kind: ArtifactErrorKind::BadMagic, .. }),
            "{err:?}"
        );
        // Bit-flip under a valid header: snapshots are written atomically,
        // so a checksum failure is corruption — typed Malformed, never a
        // silent torn-tail truncation.
        let log = SharedMemFile::new();
        let snap = SharedMemFile::new();
        let opts = WalOptions { sync_every: 1, compact_min_bytes: 256, compact_ratio: 2 };
        {
            let (mut wal, _) = open_snap(&log, &snap, opts);
            for round in 0..50u64 {
                wal.log_register(&mk_set("hot", round)).unwrap();
            }
        }
        assert!(snap.raw().len() > 20);
        snap.bytes.lock().unwrap()[16] ^= 0xff;
        let err =
            Wal::open_snapshotted(Box::new(log.clone()), Box::new(snap.clone()), "mem", opts)
                .unwrap_err();
        assert!(
            matches!(&err, ServeError::Artifact { kind: ArtifactErrorKind::Malformed, .. }),
            "{err:?}"
        );
    }

    #[test]
    fn non_wal_file_is_refused_not_overwritten() {
        let file = MemWalFile { bytes: b"CLOQPKD2junkjunkjunk".to_vec() };
        let err = Wal::open(Box::new(file), "mem", WalOptions::default()).unwrap_err();
        assert!(
            matches!(&err, ServeError::Artifact { kind: ArtifactErrorKind::BadMagic, .. }),
            "{err:?}"
        );
        let file = MemWalFile { bytes: b"CLOQWAL1\x09\x00\x00\x00".to_vec() };
        let err = Wal::open(Box::new(file), "mem", WalOptions::default()).unwrap_err();
        assert!(
            matches!(&err, ServeError::Artifact { kind: ArtifactErrorKind::BadVersion, .. }),
            "{err:?}"
        );
    }
}

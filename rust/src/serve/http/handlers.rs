//! Endpoint handlers: the mapping from parsed wire requests onto the
//! typed façade.
//!
//! Every handler invocation produces EXACTLY ONE response for its request
//! sequence number — either synchronously (admin endpoints, validation
//! failures) or from the engine's completion callback (inference
//! endpoints) — pushed into the connection's [`Rail`]. Inference
//! dispatch is non-blocking end to end: the handler returns the moment
//! the engine admits the request, and the engine worker that completes
//! it serializes the response. The tenant's quota slot travels inside
//! the completion callback, so it is released exactly when the engine
//! answers, never earlier.
//!
//! Request decode strategy (two tiers, on purpose):
//! * inference bodies (`/v1/submit`, `/v1/forward`, `/v1/session`) go
//!   through the lazy [`scan`] pass — no JSON tree is ever built on the
//!   hot path;
//! * the adapter-registration body (rare, nested, two matrices per
//!   layer) uses the full [`crate::util::json`] parser.

use std::sync::Arc;

use crate::linalg::Matrix;
use crate::lowrank::LoraPair;
use crate::serve::adapters::AdapterSet;
use crate::serve::completion::Completion;
use crate::serve::engine::Response;
use crate::serve::error::ServeError;
use crate::serve::forward::{ModelRequest, ModelResponse, SessionRequest, StepFn};
use crate::serve::generate::{GenEvent, GenParams, GenRequest, GenResponse, GenTicket, Sampling};
use crate::serve::http::auth::QuotaGuard;
use crate::serve::http::{error_body, error_response, respond, respond_raw, scan, wire};
use crate::serve::http::{ChunkStream, Rail, ServerShared};
use crate::serve::packed::Route;
use crate::serve::telemetry::Counter;
use crate::util::json::{self, Json};

/// Route and dispatch one request; guarantees exactly one `rail.push`
/// for `seq` (sync or via completion callback).
pub(crate) fn handle(shared: &Arc<ServerShared>, req: wire::Request, rail: &Arc<Rail>, seq: u64) {
    let keep = req.keep_alive;
    let bytes = match route(shared, &req, rail, seq) {
        Routed::Deferred => return, // a completion callback owns the push
        Routed::Now(bytes) => bytes,
        Routed::Engine(e) => error_response(&shared.telemetry, &e, keep),
    };
    rail.push(seq, bytes);
}

/// What routing produced: an immediate response, a typed engine error
/// (mapped by the caller), or a deferred completion-callback response.
enum Routed {
    Now(Vec<u8>),
    Engine(ServeError),
    Deferred,
}

impl From<ServeError> for Routed {
    fn from(e: ServeError) -> Routed {
        Routed::Engine(e)
    }
}

fn route(shared: &Arc<ServerShared>, req: &wire::Request, rail: &Arc<Rail>, seq: u64) -> Routed {
    let tel = &shared.telemetry;
    let keep = req.keep_alive;
    let path = req.target.split('?').next().unwrap_or("");

    // /metrics is the unauthenticated Prometheus scrape endpoint (see
    // the auth module docs for why).
    if path == "/metrics" {
        if req.method != "GET" {
            return method_not_allowed(shared, keep);
        }
        let text = shared.engine.telemetry().render_prometheus();
        let bytes = text.as_bytes();
        return Routed::Now(respond_raw(tel, 200, "text/plain; version=0.0.4", bytes, keep));
    }

    // Everything under /v1/ requires a tenant bearer token.
    let tenant = match shared.tenants.authenticate(req.bearer.as_deref()) {
        Some(t) => t,
        None => {
            tel.incr(Counter::HttpAuthRejects);
            let body = error_body("unauthorized", "missing or unknown bearer token");
            return Routed::Now(respond(tel, 401, &body, keep));
        }
    };

    match (req.method.as_str(), path) {
        ("GET", "/v1/stats") => Routed::Now(stats_response(shared, keep)),
        ("POST", "/v1/submit") => {
            let guard = match tenant.try_acquire() {
                Some(g) => g,
                None => return quota_exceeded(shared, keep),
            };
            submit(shared, req, rail, seq, guard)
        }
        ("POST", "/v1/forward") => {
            let guard = match tenant.try_acquire() {
                Some(g) => g,
                None => return quota_exceeded(shared, keep),
            };
            forward(shared, req, rail, seq, guard, false)
        }
        ("POST", "/v1/session") => {
            let guard = match tenant.try_acquire() {
                Some(g) => g,
                None => return quota_exceeded(shared, keep),
            };
            forward(shared, req, rail, seq, guard, true)
        }
        ("POST", "/v1/generate") => {
            let guard = match tenant.try_acquire() {
                Some(g) => g,
                None => return quota_exceeded(shared, keep),
            };
            generate(shared, req, rail, seq, guard)
        }
        (method, p) if p.starts_with("/v1/adapters/") => {
            let id = &p["/v1/adapters/".len()..];
            if id.is_empty() || id.contains('/') {
                let body = error_body("no-such-endpoint", "adapter id missing in path");
                return Routed::Now(respond(tel, 404, &body, keep));
            }
            match method {
                "PUT" => adapter_register(shared, req, id, keep, false),
                "POST" => adapter_register(shared, req, id, keep, true),
                "DELETE" => match shared.engine.unregister_adapter(id) {
                    Ok(()) => Routed::Now(respond(
                        tel,
                        200,
                        &Json::from_pairs(vec![("unregistered", Json::from(id))]),
                        keep,
                    )),
                    Err(e) => Routed::Engine(e),
                },
                _ => method_not_allowed(shared, keep),
            }
        }
        (_, "/v1/submit" | "/v1/forward" | "/v1/session" | "/v1/generate" | "/v1/stats") => {
            method_not_allowed(shared, keep)
        }
        _ => {
            let body = error_body("no-such-endpoint", &format!("no endpoint at {path}"));
            Routed::Now(respond(tel, 404, &body, keep))
        }
    }
}

fn method_not_allowed(shared: &ServerShared, keep: bool) -> Routed {
    let body = error_body("method-not-allowed", "method not allowed for this endpoint");
    Routed::Now(respond(&shared.telemetry, 405, &body, keep))
}

fn quota_exceeded(shared: &ServerShared, keep: bool) -> Routed {
    shared.telemetry.incr(Counter::HttpQuotaRejects);
    let body = error_body(
        "quota-exceeded",
        "tenant in-flight quota exhausted; wait for outstanding requests",
    );
    Routed::Now(respond(&shared.telemetry, 429, &body, keep))
}

fn bad_body(shared: &ServerShared, e: &scan::ScanError, keep: bool) -> Routed {
    let body = error_body("bad-json", &e.to_string());
    Routed::Now(respond(&shared.telemetry, 400, &body, keep))
}

fn missing_field(shared: &ServerShared, field: &str, keep: bool) -> Routed {
    let body = error_body("missing-field", &format!("'{field}' is required"));
    Routed::Now(respond(&shared.telemetry, 400, &body, keep))
}

/// POST /v1/submit — single-layer inference via the lazy scanner.
fn submit(
    shared: &Arc<ServerShared>,
    req: &wire::Request,
    rail: &Arc<Rail>,
    seq: u64,
    guard: QuotaGuard,
) -> Routed {
    let keep = req.keep_alive;
    let body = &req.body;
    let layer = match scan::str_field(body, "layer") {
        Err(e) => return bad_body(shared, &e, keep),
        Ok(None) => return missing_field(shared, "layer", keep),
        Ok(Some(name)) => name,
    };
    let x = match scan::f64_array_field(body, "x") {
        Err(e) => return bad_body(shared, &e, keep),
        Ok(None) => return missing_field(shared, "x", keep),
        Ok(Some(x)) => x,
    };
    let adapter = match scan::str_field(body, "adapter") {
        Err(e) => return bad_body(shared, &e, keep),
        Ok(name) => name,
    };
    let lid = match shared.engine.layer(&layer) {
        Ok(lid) => lid,
        Err(e) => return e.into(),
    };
    let aid = match adapter {
        None => None,
        Some(name) => match shared.engine.adapter(&name) {
            Ok(aid) => Some(aid),
            Err(e) => return e.into(),
        },
    };
    let ticket = shared.engine.submit(lid, aid, x);
    defer(shared, rail, seq, keep, guard, ticket, submit_response_json);
    Routed::Deferred
}

/// POST /v1/forward and /v1/session — full-model inference. A session is
/// a forward with `steps > 1` bridged by the built-in identity step
/// (`y_k` becomes `x_{k+1}` verbatim), which requires a loopable route:
/// the tail layer's output width must equal the head layer's input
/// width.
fn forward(
    shared: &Arc<ServerShared>,
    req: &wire::Request,
    rail: &Arc<Rail>,
    seq: u64,
    guard: QuotaGuard,
    session: bool,
) -> Routed {
    let keep = req.keep_alive;
    let body = &req.body;
    let names = match scan::str_array_field(body, "route") {
        Err(e) => return bad_body(shared, &e, keep),
        Ok(None) => return missing_field(shared, "route", keep),
        Ok(Some(names)) => names,
    };
    let x = match scan::f64_array_field(body, "x") {
        Err(e) => return bad_body(shared, &e, keep),
        Ok(None) => return missing_field(shared, "x", keep),
        Ok(Some(x)) => x,
    };
    let adapter = match scan::str_field(body, "adapter") {
        Err(e) => return bad_body(shared, &e, keep),
        Ok(name) => name,
    };
    let steps = if session {
        match scan::u64_field(body, "steps") {
            Err(e) => return bad_body(shared, &e, keep),
            Ok(None) => return missing_field(shared, "steps", keep),
            Ok(Some(s)) => s as usize,
        }
    } else {
        1
    };
    let route = match shared.engine.route(&names) {
        Ok(r) => r,
        Err(e) => return e.into(),
    };
    let aid = match adapter {
        None => None,
        Some(name) => match shared.engine.adapter(&name) {
            Ok(aid) => Some(aid),
            Err(e) => return e.into(),
        },
    };
    if steps > 1 {
        if let Err(e) = check_loopable(shared, &route) {
            return e.into();
        }
    }
    let ticket = if session {
        let step: StepFn = Box::new(|_, y| Some(y.to_vec()));
        let sreq = match aid {
            Some(aid) => SessionRequest::with_adapter(route, aid, x, steps, step),
            None => SessionRequest::new(route, x, steps, step),
        };
        shared.engine.submit_session(sreq)
    } else {
        let mreq = match aid {
            Some(aid) => ModelRequest::with_adapter(route, aid, x),
            None => ModelRequest::new(route, x),
        };
        shared.engine.submit_model(mreq)
    };
    defer(shared, rail, seq, keep, guard, ticket, forward_response_json);
    Routed::Deferred
}

/// POST /v1/generate — token-level autoregressive decode. Body:
/// `{"route": [...], "prompt": "...", "max_tokens": n}` plus optional
/// `adapter`, `sampling` (`"greedy"` | `"temperature"` | `"top_k"`),
/// `temperature`, `top_k`, `seed`, `stop` (array of strings), and
/// `stream` (bool, default false).
///
/// Non-streaming replies ride the ordinary [`defer`] path: one JSON
/// object when the session finishes. With `"stream": true` the reply is
/// `Transfer-Encoding: chunked`, one NDJSON event per chunk — token
/// events as they are sampled, then a final `{"done": true, ...}`
/// summary — and an early client disconnect cancels the session at the
/// next token boundary via the stream's client-gone hook.
///
/// Uses the full JSON parser, not the lazy [`scan`] pass: generate
/// bodies are small (a prompt and knobs, no activation vectors), and the
/// per-request cost is dwarfed by the decode loop it starts.
///
/// Unlike `/v1/session`, the route does NOT need to be loopable: the
/// decode loop re-enters the model through the hash-embedding state
/// (head-width by construction), not by feeding the tail's output back
/// verbatim.
fn generate(
    shared: &Arc<ServerShared>,
    req: &wire::Request,
    rail: &Arc<Rail>,
    seq: u64,
    guard: QuotaGuard,
) -> Routed {
    let keep = req.keep_alive;
    let bad = |msg: &str| -> Routed {
        let body = error_body("bad-json", msg);
        Routed::Now(respond(&shared.telemetry, 400, &body, keep))
    };
    let text = match std::str::from_utf8(&req.body) {
        Ok(t) => t,
        Err(_) => return bad("body is not UTF-8"),
    };
    let tree = match json::parse(text) {
        Ok(t) => t,
        Err(e) => return bad(&format!("malformed JSON: {e}")),
    };
    let names = match tree.get("route").and_then(Json::as_arr) {
        None => return missing_field(shared, "route", keep),
        Some(arr) => {
            let mut names = Vec::with_capacity(arr.len());
            for v in arr {
                match v.as_str() {
                    Some(s) => names.push(s.to_string()),
                    None => return bad("'route' must be an array of layer names"),
                }
            }
            names
        }
    };
    let prompt = match tree.get("prompt").and_then(Json::as_str) {
        Some(p) => p.to_string(),
        None => return missing_field(shared, "prompt", keep),
    };
    // An explicit cap is required: an unbounded decode loop is a cost
    // decision the client must make, not a server default.
    let max_tokens = match tree.get("max_tokens").and_then(Json::as_usize) {
        Some(n) => n,
        None => return missing_field(shared, "max_tokens", keep),
    };
    let sampling = match tree.get("sampling").map(Json::as_str) {
        None | Some(Some("greedy")) => Sampling::Greedy,
        Some(Some("temperature")) => Sampling::Temperature {
            t: tree.get("temperature").and_then(Json::as_f64).unwrap_or(1.0),
        },
        Some(Some("top_k")) => {
            let k = match tree.get("top_k").and_then(Json::as_usize) {
                Some(k) => k,
                None => return missing_field(shared, "top_k", keep),
            };
            Sampling::TopK { k, t: tree.get("temperature").and_then(Json::as_f64).unwrap_or(1.0) }
        }
        Some(_) => return bad("'sampling' must be \"greedy\", \"temperature\", or \"top_k\""),
    };
    let seed = tree.get("seed").and_then(Json::as_usize).unwrap_or(0) as u64;
    let mut params = GenParams::greedy(max_tokens).sampling(sampling).seed(seed);
    if let Some(stops) = tree.get("stop") {
        let arr = match stops.as_arr() {
            Some(a) => a,
            None => return bad("'stop' must be an array of strings"),
        };
        for s in arr {
            match s.as_str() {
                Some(s) => params = params.stop(s),
                None => return bad("'stop' must be an array of strings"),
            }
        }
    }
    let stream_mode = tree.get("stream").and_then(Json::as_bool).unwrap_or(false);
    // Resolve route and adapter BEFORE any response byte: every
    // validation failure must answer as a typed JSON error, and once a
    // chunked 200 head is on the wire the status is spent.
    let route = match shared.engine.route(&names) {
        Ok(r) => r,
        Err(e) => return e.into(),
    };
    let aid = match tree.get("adapter").and_then(Json::as_str) {
        None => None,
        Some(name) => match shared.engine.adapter(name) {
            Ok(aid) => Some(aid),
            Err(e) => return e.into(),
        },
    };
    let greq = match aid {
        Some(aid) => GenRequest::with_adapter(route, aid, &prompt, params),
        None => GenRequest::new(route, &prompt, params),
    };
    if !stream_mode {
        let ticket = shared.engine.generate(greq);
        defer(shared, rail, seq, keep, guard, ticket, generate_response_json);
        return Routed::Deferred;
    }
    let mut ticket = shared.engine.generate(greq);
    // Admission failures resolve inline, before any token; answer them
    // as plain typed errors rather than a 200 stream that opens with an
    // error event. (An inline Ok — the whole session already finished —
    // is fine: its events are buffered in the token stream.)
    if let Some(Err(e)) = ticket.try_wait() {
        return Routed::Engine(e);
    }
    let ticket = Arc::new(ticket);
    let hook = {
        let t = Arc::clone(&ticket);
        Box::new(move || t.cancel()) as Box<dyn FnOnce() + Send>
    };
    let out = ChunkStream::new(hook);
    out.push(wire::write_chunked_head(200, "application/x-ndjson", keep));
    rail.push_stream(seq, Arc::clone(&out));
    // Streaming bypasses respond_raw, so tick the status class here: the
    // 200 is committed the moment the head enters the stream.
    shared.telemetry.incr(Counter::HttpOk);
    pump_stream(ticket, out, guard);
    Routed::Deferred
}

/// Relay token events from a generation into the connection's chunk
/// stream, one NDJSON line per chunk. Runs on whichever thread resolves
/// each token ticket — engine workers, after the first hop — and parks
/// nothing between tokens: draining buffered events with `try_wait`,
/// then installing the next event's completion callback, which re-enters
/// the pump.
fn pump_stream(ticket: Arc<GenTicket>, out: Arc<ChunkStream>, guard: QuotaGuard) {
    let mut next = ticket.next_token();
    loop {
        match next.try_wait() {
            Some(ev) => {
                if emit_gen_event(&out, ev) {
                    drop(guard); // terminal: release the tenant slot
                    return;
                }
                next = ticket.next_token();
            }
            None => break,
        }
    }
    let t = Arc::clone(&ticket);
    next.on_complete(Box::new(move |ev| {
        if emit_gen_event(&out, ev) {
            drop(guard);
            return;
        }
        pump_stream(t, out, guard);
    }));
}

/// Frame one generation event as an NDJSON chunk. Returns true when the
/// event was terminal: the chunked-body terminator has been written and
/// the stream closed.
fn emit_gen_event(out: &ChunkStream, ev: Result<GenEvent, ServeError>) -> bool {
    let (line, terminal) = match ev {
        Ok(GenEvent::Token { index, token, piece }) => (
            Json::from_pairs(vec![
                ("index", Json::from(index)),
                ("token", Json::from(token as i64)),
                ("piece", Json::from(piece.as_str())),
            ]),
            false,
        ),
        Ok(GenEvent::Done(resp)) => {
            let mut done = generate_response_json(&resp);
            done.set("done", Json::from(true));
            (done, true)
        }
        Err(e) => {
            let mut body = error_body(e.code(), &e.to_string());
            body.set("error", Json::from(true));
            (body, true)
        }
    };
    let mut data = line.to_string_compact().into_bytes();
    data.push(b'\n');
    out.push(wire::write_chunk(&data));
    if terminal {
        out.push(wire::write_last_chunk());
        out.close();
    }
    terminal
}

/// The generation summary on the wire. Deliberately omits the final
/// logits vector (`GenResponse::y`): it is the in-process 0-ULP parity
/// anchor, not client-facing data, and can be as wide as the vocabulary.
fn generate_response_json(resp: &GenResponse) -> Json {
    Json::from_pairs(vec![
        ("text", Json::from(resp.text.as_str())),
        ("tokens", Json::Arr(resp.tokens.iter().map(|&t| Json::from(t as i64)).collect())),
        ("finish", Json::from(resp.finish.as_str())),
        ("prompt_tokens", Json::from(resp.prompt_tokens)),
        ("ttft_s", Json::from(resp.ttft_s)),
        ("forwards", Json::from(resp.forwards)),
        ("hops", Json::from(resp.hops)),
        ("queue_s", Json::from(resp.queue_s)),
        ("compute_s", Json::from(resp.compute_s)),
        ("wall_s", Json::from(resp.wall_s)),
        ("max_batch_seen", Json::from(resp.max_batch_seen)),
        ("mixed_hops", Json::from(resp.mixed_hops)),
        ("trace_id", Json::from(resp.trace_id as f64)),
    ])
}

/// A multi-step HTTP session reuses each forward's output as the next
/// input verbatim, so the route must chain tail→head.
fn check_loopable(shared: &ServerShared, route: &Route) -> Result<(), ServeError> {
    let ids = route.as_ids();
    let model = shared.engine.model();
    let head = model.get(ids[0]).expect("route validated against this engine");
    let tail = model.get(*ids.last().expect("routes are non-empty")).expect("validated");
    if tail.cols != head.rows {
        return Err(ServeError::InvalidConfig {
            detail: format!(
                "multi-step session needs a loopable route: tail '{}' emits {} values but \
                 head '{}' takes {}",
                tail.name, tail.cols, head.name, head.rows
            ),
        });
    }
    Ok(())
}

/// Attach the completion callback that serializes the engine's reply
/// into the rail slot. The quota guard rides inside the callback: it
/// drops — releasing the tenant's in-flight slot — exactly when the
/// engine resolves the request.
fn defer<C>(
    shared: &Arc<ServerShared>,
    rail: &Arc<Rail>,
    seq: u64,
    keep: bool,
    guard: QuotaGuard,
    ticket: C,
    to_json: fn(&C::Output) -> Json,
) where
    C: Completion,
{
    let tel = Arc::clone(&shared.telemetry);
    let rail = Arc::clone(rail);
    ticket.on_complete(Box::new(move |result| {
        let _release_at_completion = guard;
        let bytes = match result {
            Ok(resp) => respond(&tel, 200, &to_json(&resp), keep),
            Err(e) => error_response(&tel, &e, keep),
        };
        rail.push(seq, bytes);
    }));
}

fn arr_f64(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&v| Json::from(v)).collect())
}

fn submit_response_json(resp: &Response) -> Json {
    Json::from_pairs(vec![
        ("y", arr_f64(&resp.y)),
        ("queue_s", Json::from(resp.queue_s)),
        ("compute_s", Json::from(resp.compute_s)),
        ("batch_size", Json::from(resp.batch_size)),
        ("adapter_groups", Json::from(resp.adapter_groups)),
        ("trace_id", Json::from(resp.trace_id as f64)),
    ])
}

fn forward_response_json(resp: &ModelResponse) -> Json {
    Json::from_pairs(vec![
        ("y", arr_f64(&resp.y)),
        ("forwards", Json::from(resp.forwards)),
        ("hops", Json::from(resp.hops)),
        ("queue_s", Json::from(resp.queue_s)),
        ("compute_s", Json::from(resp.compute_s)),
        ("wall_s", Json::from(resp.wall_s)),
        ("max_batch_seen", Json::from(resp.max_batch_seen)),
        ("mixed_hops", Json::from(resp.mixed_hops)),
        ("trace_id", Json::from(resp.trace_id as f64)),
    ])
}

fn stats_response(shared: &ServerShared, keep: bool) -> Vec<u8> {
    let s = shared.engine.stats();
    let body = Json::from_pairs(vec![
        ("requests", Json::from(s.requests)),
        ("model_requests", Json::from(s.model_requests)),
        ("session_forwards", Json::from(s.session_forwards)),
        ("hops", Json::from(s.hops)),
        ("batches", Json::from(s.batches)),
        ("max_batch_seen", Json::from(s.max_batch_seen)),
        ("mixed_batches", Json::from(s.mixed_batches)),
        ("rejected", Json::from(s.rejected)),
        ("batch_panics", Json::from(s.batch_panics)),
        ("failed", Json::from(s.failed)),
        ("failed_model_requests", Json::from(s.failed_model_requests)),
        ("mean_batch", Json::from(s.mean_batch())),
        ("total_queue_s", Json::from(s.total_queue_s)),
        ("total_compute_s", Json::from(s.total_compute_s)),
    ]);
    respond(&shared.telemetry, 200, &body, keep)
}

/// PUT (register; 409 if present) / POST (hot-swap; 404 if absent)
/// `/v1/adapters/{id}`. Body:
/// `{"layers": [{"layer": "...", "rank": r, "a": [m*r], "b": [n*r]}]}`
/// with `a`/`b` flattened row-major against the named layer's m×n shape.
fn adapter_register(
    shared: &Arc<ServerShared>,
    req: &wire::Request,
    id: &str,
    keep: bool,
    hot_swap: bool,
) -> Routed {
    let tel = &shared.telemetry;
    let exists = shared.engine.registry().contains(id);
    if hot_swap && !exists {
        return Routed::Engine(ServeError::UnknownAdapter { adapter: id.to_string() });
    }
    if !hot_swap && exists {
        let body = error_body(
            "already-registered",
            &format!("adapter '{id}' exists; POST to hot-swap it"),
        );
        return Routed::Now(respond(tel, 409, &body, keep));
    }
    let set = match parse_adapter_set(shared, id, &req.body) {
        Ok(set) => set,
        Err(r) => return r,
    };
    match shared.engine.register_adapter(set) {
        Ok(outcome) => {
            let evicted =
                Json::Arr(outcome.evicted.iter().map(|n| Json::from(n.as_str())).collect());
            let body = Json::from_pairs(vec![
                ("adapter", Json::from(id)),
                ("replaced", Json::from(outcome.replaced)),
                ("evicted", evicted),
            ]);
            Routed::Now(respond(tel, 200, &body, keep))
        }
        Err(e) => Routed::Engine(e),
    }
}

fn parse_adapter_set(
    shared: &Arc<ServerShared>,
    id: &str,
    body: &[u8],
) -> Result<AdapterSet, Routed> {
    let bad = |msg: &str| -> Routed {
        let body = error_body("bad-json", msg);
        Routed::Now(respond(&shared.telemetry, 400, &body, true))
    };
    let text = std::str::from_utf8(body).map_err(|_| bad("body is not UTF-8"))?;
    let tree = json::parse(text).map_err(|e| bad(&format!("malformed JSON: {e}")))?;
    let layers = tree
        .get("layers")
        .and_then(Json::as_arr)
        .ok_or_else(|| bad("'layers' must be an array of per-layer factor objects"))?;
    let mut set = AdapterSet::new(id);
    for entry in layers {
        let name = entry
            .get("layer")
            .and_then(Json::as_str)
            .ok_or_else(|| bad("each layer entry needs a 'layer' name"))?;
        let rank = entry
            .get("rank")
            .and_then(Json::as_usize)
            .ok_or_else(|| bad("each layer entry needs an integer 'rank'"))?;
        let a = f64s(entry.get("a")).ok_or_else(|| bad("'a' must be an array of numbers"))?;
        let b = f64s(entry.get("b")).ok_or_else(|| bad("'b' must be an array of numbers"))?;
        let pl = shared
            .engine
            .model()
            .layer(name)
            .ok_or_else(|| Routed::Engine(ServeError::UnknownLayer { layer: name.to_string() }))?;
        if rank == 0 || a.len() != pl.rows * rank || b.len() != pl.cols * rank {
            return Err(Routed::Engine(ServeError::ShapeMismatch {
                layer: name.to_string(),
                detail: format!(
                    "adapter factors must be a[{}x{rank}], b[{}x{rank}] flattened; got a[{}], \
                     b[{}]",
                    pl.rows,
                    pl.cols,
                    a.len(),
                    b.len()
                ),
            }));
        }
        let pair = LoraPair::new(
            Matrix::from_vec(pl.rows, rank, a),
            Matrix::from_vec(pl.cols, rank, b),
        );
        if let Err(e) = set.insert(name, pair) {
            return Err(Routed::Engine(e));
        }
    }
    Ok(set)
}

fn f64s(v: Option<&Json>) -> Option<Vec<f64>> {
    v?.as_arr()?.iter().map(Json::as_f64).collect()
}

//! Endpoint handlers: the mapping from parsed wire requests onto the
//! typed façade.
//!
//! Every handler invocation produces EXACTLY ONE response for its request
//! sequence number — either synchronously (admin endpoints, validation
//! failures) or from the engine's completion callback (inference
//! endpoints) — pushed into the connection's [`Rail`]. Inference
//! dispatch is non-blocking end to end: the handler returns the moment
//! the engine admits the request, and the engine worker that completes
//! it serializes the response. The tenant's quota slot travels inside
//! the completion callback, so it is released exactly when the engine
//! answers, never earlier.
//!
//! Request decode strategy (two tiers, on purpose):
//! * inference bodies (`/v1/submit`, `/v1/forward`, `/v1/session`) go
//!   through the lazy [`scan`] pass — no JSON tree is ever built on the
//!   hot path;
//! * the adapter-registration body (rare, nested, two matrices per
//!   layer) uses the full [`crate::util::json`] parser.

use std::sync::Arc;

use crate::linalg::Matrix;
use crate::lowrank::LoraPair;
use crate::serve::adapters::AdapterSet;
use crate::serve::completion::Completion;
use crate::serve::engine::Response;
use crate::serve::error::ServeError;
use crate::serve::forward::{ModelRequest, ModelResponse, SessionRequest, StepFn};
use crate::serve::http::auth::QuotaGuard;
use crate::serve::http::{error_body, error_response, respond, respond_raw, scan, wire};
use crate::serve::http::{Rail, ServerShared};
use crate::serve::packed::Route;
use crate::serve::telemetry::Counter;
use crate::util::json::{self, Json};

/// Route and dispatch one request; guarantees exactly one `rail.push`
/// for `seq` (sync or via completion callback).
pub(crate) fn handle(shared: &Arc<ServerShared>, req: wire::Request, rail: &Arc<Rail>, seq: u64) {
    let keep = req.keep_alive;
    let bytes = match route(shared, &req, rail, seq) {
        Routed::Deferred => return, // a completion callback owns the push
        Routed::Now(bytes) => bytes,
        Routed::Engine(e) => error_response(&shared.telemetry, &e, keep),
    };
    rail.push(seq, bytes);
}

/// What routing produced: an immediate response, a typed engine error
/// (mapped by the caller), or a deferred completion-callback response.
enum Routed {
    Now(Vec<u8>),
    Engine(ServeError),
    Deferred,
}

impl From<ServeError> for Routed {
    fn from(e: ServeError) -> Routed {
        Routed::Engine(e)
    }
}

fn route(shared: &Arc<ServerShared>, req: &wire::Request, rail: &Arc<Rail>, seq: u64) -> Routed {
    let tel = &shared.telemetry;
    let keep = req.keep_alive;
    let path = req.target.split('?').next().unwrap_or("");

    // /metrics is the unauthenticated Prometheus scrape endpoint (see
    // the auth module docs for why).
    if path == "/metrics" {
        if req.method != "GET" {
            return method_not_allowed(shared, keep);
        }
        let text = shared.engine.telemetry().render_prometheus();
        let bytes = text.as_bytes();
        return Routed::Now(respond_raw(tel, 200, "text/plain; version=0.0.4", bytes, keep));
    }

    // Everything under /v1/ requires a tenant bearer token.
    let tenant = match shared.tenants.authenticate(req.bearer.as_deref()) {
        Some(t) => t,
        None => {
            tel.incr(Counter::HttpAuthRejects);
            let body = error_body("unauthorized", "missing or unknown bearer token");
            return Routed::Now(respond(tel, 401, &body, keep));
        }
    };

    match (req.method.as_str(), path) {
        ("GET", "/v1/stats") => Routed::Now(stats_response(shared, keep)),
        ("POST", "/v1/submit") => {
            let guard = match tenant.try_acquire() {
                Some(g) => g,
                None => return quota_exceeded(shared, keep),
            };
            submit(shared, req, rail, seq, guard)
        }
        ("POST", "/v1/forward") => {
            let guard = match tenant.try_acquire() {
                Some(g) => g,
                None => return quota_exceeded(shared, keep),
            };
            forward(shared, req, rail, seq, guard, false)
        }
        ("POST", "/v1/session") => {
            let guard = match tenant.try_acquire() {
                Some(g) => g,
                None => return quota_exceeded(shared, keep),
            };
            forward(shared, req, rail, seq, guard, true)
        }
        (method, p) if p.starts_with("/v1/adapters/") => {
            let id = &p["/v1/adapters/".len()..];
            if id.is_empty() || id.contains('/') {
                let body = error_body("no-such-endpoint", "adapter id missing in path");
                return Routed::Now(respond(tel, 404, &body, keep));
            }
            match method {
                "PUT" => adapter_register(shared, req, id, keep, false),
                "POST" => adapter_register(shared, req, id, keep, true),
                "DELETE" => match shared.engine.unregister_adapter(id) {
                    Ok(()) => Routed::Now(respond(
                        tel,
                        200,
                        &Json::from_pairs(vec![("unregistered", Json::from(id))]),
                        keep,
                    )),
                    Err(e) => Routed::Engine(e),
                },
                _ => method_not_allowed(shared, keep),
            }
        }
        (_, "/v1/submit" | "/v1/forward" | "/v1/session" | "/v1/stats") => {
            method_not_allowed(shared, keep)
        }
        _ => {
            let body = error_body("no-such-endpoint", &format!("no endpoint at {path}"));
            Routed::Now(respond(tel, 404, &body, keep))
        }
    }
}

fn method_not_allowed(shared: &ServerShared, keep: bool) -> Routed {
    let body = error_body("method-not-allowed", "method not allowed for this endpoint");
    Routed::Now(respond(&shared.telemetry, 405, &body, keep))
}

fn quota_exceeded(shared: &ServerShared, keep: bool) -> Routed {
    shared.telemetry.incr(Counter::HttpQuotaRejects);
    let body = error_body(
        "quota-exceeded",
        "tenant in-flight quota exhausted; wait for outstanding requests",
    );
    Routed::Now(respond(&shared.telemetry, 429, &body, keep))
}

fn bad_body(shared: &ServerShared, e: &scan::ScanError, keep: bool) -> Routed {
    let body = error_body("bad-json", &e.to_string());
    Routed::Now(respond(&shared.telemetry, 400, &body, keep))
}

fn missing_field(shared: &ServerShared, field: &str, keep: bool) -> Routed {
    let body = error_body("missing-field", &format!("'{field}' is required"));
    Routed::Now(respond(&shared.telemetry, 400, &body, keep))
}

/// POST /v1/submit — single-layer inference via the lazy scanner.
fn submit(
    shared: &Arc<ServerShared>,
    req: &wire::Request,
    rail: &Arc<Rail>,
    seq: u64,
    guard: QuotaGuard,
) -> Routed {
    let keep = req.keep_alive;
    let body = &req.body;
    let layer = match scan::str_field(body, "layer") {
        Err(e) => return bad_body(shared, &e, keep),
        Ok(None) => return missing_field(shared, "layer", keep),
        Ok(Some(name)) => name,
    };
    let x = match scan::f64_array_field(body, "x") {
        Err(e) => return bad_body(shared, &e, keep),
        Ok(None) => return missing_field(shared, "x", keep),
        Ok(Some(x)) => x,
    };
    let adapter = match scan::str_field(body, "adapter") {
        Err(e) => return bad_body(shared, &e, keep),
        Ok(name) => name,
    };
    let lid = match shared.engine.layer(&layer) {
        Ok(lid) => lid,
        Err(e) => return e.into(),
    };
    let aid = match adapter {
        None => None,
        Some(name) => match shared.engine.adapter(&name) {
            Ok(aid) => Some(aid),
            Err(e) => return e.into(),
        },
    };
    let ticket = shared.engine.submit(lid, aid, x);
    defer(shared, rail, seq, keep, guard, ticket, submit_response_json);
    Routed::Deferred
}

/// POST /v1/forward and /v1/session — full-model inference. A session is
/// a forward with `steps > 1` bridged by the built-in identity step
/// (`y_k` becomes `x_{k+1}` verbatim), which requires a loopable route:
/// the tail layer's output width must equal the head layer's input
/// width.
fn forward(
    shared: &Arc<ServerShared>,
    req: &wire::Request,
    rail: &Arc<Rail>,
    seq: u64,
    guard: QuotaGuard,
    session: bool,
) -> Routed {
    let keep = req.keep_alive;
    let body = &req.body;
    let names = match scan::str_array_field(body, "route") {
        Err(e) => return bad_body(shared, &e, keep),
        Ok(None) => return missing_field(shared, "route", keep),
        Ok(Some(names)) => names,
    };
    let x = match scan::f64_array_field(body, "x") {
        Err(e) => return bad_body(shared, &e, keep),
        Ok(None) => return missing_field(shared, "x", keep),
        Ok(Some(x)) => x,
    };
    let adapter = match scan::str_field(body, "adapter") {
        Err(e) => return bad_body(shared, &e, keep),
        Ok(name) => name,
    };
    let steps = if session {
        match scan::u64_field(body, "steps") {
            Err(e) => return bad_body(shared, &e, keep),
            Ok(None) => return missing_field(shared, "steps", keep),
            Ok(Some(s)) => s as usize,
        }
    } else {
        1
    };
    let route = match shared.engine.route(&names) {
        Ok(r) => r,
        Err(e) => return e.into(),
    };
    let aid = match adapter {
        None => None,
        Some(name) => match shared.engine.adapter(&name) {
            Ok(aid) => Some(aid),
            Err(e) => return e.into(),
        },
    };
    if steps > 1 {
        if let Err(e) = check_loopable(shared, &route) {
            return e.into();
        }
    }
    let ticket = if session {
        let step: StepFn = Box::new(|_, y| Some(y.to_vec()));
        let sreq = match aid {
            Some(aid) => SessionRequest::with_adapter(route, aid, x, steps, step),
            None => SessionRequest::new(route, x, steps, step),
        };
        shared.engine.submit_session(sreq)
    } else {
        let mreq = match aid {
            Some(aid) => ModelRequest::with_adapter(route, aid, x),
            None => ModelRequest::new(route, x),
        };
        shared.engine.submit_model(mreq)
    };
    defer(shared, rail, seq, keep, guard, ticket, forward_response_json);
    Routed::Deferred
}

/// A multi-step HTTP session reuses each forward's output as the next
/// input verbatim, so the route must chain tail→head.
fn check_loopable(shared: &ServerShared, route: &Route) -> Result<(), ServeError> {
    let ids = route.as_ids();
    let model = shared.engine.model();
    let head = model.get(ids[0]).expect("route validated against this engine");
    let tail = model.get(*ids.last().expect("routes are non-empty")).expect("validated");
    if tail.cols != head.rows {
        return Err(ServeError::InvalidConfig {
            detail: format!(
                "multi-step session needs a loopable route: tail '{}' emits {} values but \
                 head '{}' takes {}",
                tail.name, tail.cols, head.name, head.rows
            ),
        });
    }
    Ok(())
}

/// Attach the completion callback that serializes the engine's reply
/// into the rail slot. The quota guard rides inside the callback: it
/// drops — releasing the tenant's in-flight slot — exactly when the
/// engine resolves the request.
fn defer<C>(
    shared: &Arc<ServerShared>,
    rail: &Arc<Rail>,
    seq: u64,
    keep: bool,
    guard: QuotaGuard,
    ticket: C,
    to_json: fn(&C::Output) -> Json,
) where
    C: Completion,
{
    let tel = Arc::clone(&shared.telemetry);
    let rail = Arc::clone(rail);
    ticket.on_complete(Box::new(move |result| {
        let _release_at_completion = guard;
        let bytes = match result {
            Ok(resp) => respond(&tel, 200, &to_json(&resp), keep),
            Err(e) => error_response(&tel, &e, keep),
        };
        rail.push(seq, bytes);
    }));
}

fn arr_f64(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&v| Json::from(v)).collect())
}

fn submit_response_json(resp: &Response) -> Json {
    Json::from_pairs(vec![
        ("y", arr_f64(&resp.y)),
        ("queue_s", Json::from(resp.queue_s)),
        ("compute_s", Json::from(resp.compute_s)),
        ("batch_size", Json::from(resp.batch_size)),
        ("adapter_groups", Json::from(resp.adapter_groups)),
        ("trace_id", Json::from(resp.trace_id as f64)),
    ])
}

fn forward_response_json(resp: &ModelResponse) -> Json {
    Json::from_pairs(vec![
        ("y", arr_f64(&resp.y)),
        ("forwards", Json::from(resp.forwards)),
        ("hops", Json::from(resp.hops)),
        ("queue_s", Json::from(resp.queue_s)),
        ("compute_s", Json::from(resp.compute_s)),
        ("wall_s", Json::from(resp.wall_s)),
        ("max_batch_seen", Json::from(resp.max_batch_seen)),
        ("mixed_hops", Json::from(resp.mixed_hops)),
        ("trace_id", Json::from(resp.trace_id as f64)),
    ])
}

fn stats_response(shared: &ServerShared, keep: bool) -> Vec<u8> {
    let s = shared.engine.stats();
    let body = Json::from_pairs(vec![
        ("requests", Json::from(s.requests)),
        ("model_requests", Json::from(s.model_requests)),
        ("session_forwards", Json::from(s.session_forwards)),
        ("hops", Json::from(s.hops)),
        ("batches", Json::from(s.batches)),
        ("max_batch_seen", Json::from(s.max_batch_seen)),
        ("mixed_batches", Json::from(s.mixed_batches)),
        ("rejected", Json::from(s.rejected)),
        ("batch_panics", Json::from(s.batch_panics)),
        ("failed", Json::from(s.failed)),
        ("failed_model_requests", Json::from(s.failed_model_requests)),
        ("mean_batch", Json::from(s.mean_batch())),
        ("total_queue_s", Json::from(s.total_queue_s)),
        ("total_compute_s", Json::from(s.total_compute_s)),
    ]);
    respond(&shared.telemetry, 200, &body, keep)
}

/// PUT (register; 409 if present) / POST (hot-swap; 404 if absent)
/// `/v1/adapters/{id}`. Body:
/// `{"layers": [{"layer": "...", "rank": r, "a": [m*r], "b": [n*r]}]}`
/// with `a`/`b` flattened row-major against the named layer's m×n shape.
fn adapter_register(
    shared: &Arc<ServerShared>,
    req: &wire::Request,
    id: &str,
    keep: bool,
    hot_swap: bool,
) -> Routed {
    let tel = &shared.telemetry;
    let exists = shared.engine.registry().contains(id);
    if hot_swap && !exists {
        return Routed::Engine(ServeError::UnknownAdapter { adapter: id.to_string() });
    }
    if !hot_swap && exists {
        let body = error_body(
            "already-registered",
            &format!("adapter '{id}' exists; POST to hot-swap it"),
        );
        return Routed::Now(respond(tel, 409, &body, keep));
    }
    let set = match parse_adapter_set(shared, id, &req.body) {
        Ok(set) => set,
        Err(r) => return r,
    };
    match shared.engine.register_adapter(set) {
        Ok(outcome) => {
            let evicted =
                Json::Arr(outcome.evicted.iter().map(|n| Json::from(n.as_str())).collect());
            let body = Json::from_pairs(vec![
                ("adapter", Json::from(id)),
                ("replaced", Json::from(outcome.replaced)),
                ("evicted", evicted),
            ]);
            Routed::Now(respond(tel, 200, &body, keep))
        }
        Err(e) => Routed::Engine(e),
    }
}

fn parse_adapter_set(
    shared: &Arc<ServerShared>,
    id: &str,
    body: &[u8],
) -> Result<AdapterSet, Routed> {
    let bad = |msg: &str| -> Routed {
        let body = error_body("bad-json", msg);
        Routed::Now(respond(&shared.telemetry, 400, &body, true))
    };
    let text = std::str::from_utf8(body).map_err(|_| bad("body is not UTF-8"))?;
    let tree = json::parse(text).map_err(|e| bad(&format!("malformed JSON: {e}")))?;
    let layers = tree
        .get("layers")
        .and_then(Json::as_arr)
        .ok_or_else(|| bad("'layers' must be an array of per-layer factor objects"))?;
    let mut set = AdapterSet::new(id);
    for entry in layers {
        let name = entry
            .get("layer")
            .and_then(Json::as_str)
            .ok_or_else(|| bad("each layer entry needs a 'layer' name"))?;
        let rank = entry
            .get("rank")
            .and_then(Json::as_usize)
            .ok_or_else(|| bad("each layer entry needs an integer 'rank'"))?;
        let a = f64s(entry.get("a")).ok_or_else(|| bad("'a' must be an array of numbers"))?;
        let b = f64s(entry.get("b")).ok_or_else(|| bad("'b' must be an array of numbers"))?;
        let pl = shared
            .engine
            .model()
            .layer(name)
            .ok_or_else(|| Routed::Engine(ServeError::UnknownLayer { layer: name.to_string() }))?;
        if rank == 0 || a.len() != pl.rows * rank || b.len() != pl.cols * rank {
            return Err(Routed::Engine(ServeError::ShapeMismatch {
                layer: name.to_string(),
                detail: format!(
                    "adapter factors must be a[{}x{rank}], b[{}x{rank}] flattened; got a[{}], \
                     b[{}]",
                    pl.rows,
                    pl.cols,
                    a.len(),
                    b.len()
                ),
            }));
        }
        let pair = LoraPair::new(
            Matrix::from_vec(pl.rows, rank, a),
            Matrix::from_vec(pl.cols, rank, b),
        );
        if let Err(e) = set.insert(name, pair) {
            return Err(Routed::Engine(e));
        }
    }
    Ok(set)
}

fn f64s(v: Option<&Json>) -> Option<Vec<f64>> {
    v?.as_arr()?.iter().map(Json::as_f64).collect()
}

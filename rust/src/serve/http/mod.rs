//! The HTTP serving front-end: the typed façade on the wire.
//!
//! A dependency-free HTTP/1.1 server over `std::net` (the workspace is
//! offline; vendored shims only) that exposes a [`ServeEngine`] to
//! processes that can't link it:
//!
//! | Endpoint                    | Maps onto                          |
//! |-----------------------------|------------------------------------|
//! | `POST /v1/submit`           | [`ServeEngine::submit`]            |
//! | `POST /v1/forward`          | [`ServeEngine::submit_model`]      |
//! | `POST /v1/session`          | [`ServeEngine::submit_session`]    |
//! | `POST /v1/generate`         | [`ServeEngine::generate`]          |
//! | `PUT /v1/adapters/{id}`     | [`ServeEngine::register_adapter`]  |
//! | `POST /v1/adapters/{id}`    | register (hot-swap; must exist)    |
//! | `DELETE /v1/adapters/{id}`  | [`ServeEngine::unregister_adapter`]|
//! | `GET /v1/stats`             | [`ServeEngine::stats`]             |
//! | `GET /metrics`              | [`TelemetrySnapshot::render_prometheus`] |
//!
//! # Architecture
//!
//! One **accept thread** on a bounded connection pool: past
//! `max_connections`, new connections are shed with an immediate 503 —
//! never queued into an invisible backlog. One **thread per connection**
//! (NOT per request): the connection loop feeds raw socket bytes into the
//! incremental [`wire::RequestParser`], dispatches every complete request
//! it finds, and writes responses strictly in request order through a
//! per-connection [`Rail`]. Inference requests dispatch through the
//! non-blocking [`Completion::on_complete`] callback — the engine worker
//! that finishes a request serializes its response into the rail slot —
//! so N pipelined requests on one connection are all in flight in the
//! engine simultaneously with zero parked waiter threads.
//!
//! `/v1/generate` with `"stream": true` is the one response that is not a
//! single buffer: its rail slot holds a [`ChunkStream`] that engine
//! workers fill with pre-framed `Transfer-Encoding: chunked` bytes, one
//! NDJSON token event per chunk. The connection thread drains it in
//! sequence order — pipelined responses behind a stream still cannot
//! reorder — and a socket write failure mid-stream fires the stream's
//! cancel hook, ending the generation session at the next token boundary
//! instead of decoding for a vanished client.
//!
//! Authentication, quotas, the `{code, message}` error contract, and the
//! lazy hot-path JSON decode are documented in [`auth`], [`wire`], and
//! [`scan`]; endpoint semantics in [`handlers`].
//!
//! [`Completion::on_complete`]: crate::serve::completion::Completion::on_complete
//! [`ServeEngine::submit`]: crate::serve::ServeEngine::submit
//! [`ServeEngine::submit_model`]: crate::serve::ServeEngine::submit_model
//! [`ServeEngine::submit_session`]: crate::serve::ServeEngine::submit_session
//! [`ServeEngine::generate`]: crate::serve::ServeEngine::generate
//! [`ServeEngine::register_adapter`]: crate::serve::ServeEngine::register_adapter
//! [`ServeEngine::unregister_adapter`]: crate::serve::ServeEngine::unregister_adapter
//! [`ServeEngine::stats`]: crate::serve::ServeEngine::stats
//! [`TelemetrySnapshot::render_prometheus`]: crate::serve::TelemetrySnapshot::render_prometheus

pub mod auth;
pub mod handlers;
pub mod scan;
pub mod wire;

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::Duration;

use crate::serve::engine::ServeEngine;
use crate::serve::error::ServeError;
use crate::serve::telemetry::{Counter, Telemetry};
use crate::util::json::Json;

use auth::TenantTable;

/// How long a connection thread blocks in one read before re-checking the
/// shutdown flag — the bound on shutdown latency per connection.
const READ_POLL: Duration = Duration::from_millis(25);

/// Server-wide state shared by the accept loop and every connection.
pub(crate) struct ServerShared {
    pub engine: Arc<ServeEngine>,
    pub tenants: TenantTable,
    pub telemetry: Arc<Telemetry>,
    pub max_body: usize,
    shutdown: AtomicBool,
}

/// One rail slot: either a complete, already-serialized response, or an
/// incrementally produced chunked stream (`/v1/generate` streaming).
pub(crate) enum RailSlot {
    Full(Vec<u8>),
    Stream(Arc<ChunkStream>),
}

/// Per-connection ordered response rail. Handlers (or their completion
/// callbacks, running on engine workers) push each response under its
/// request sequence number; the connection thread pops them strictly in
/// order, so pipelined responses can never interleave or reorder on the
/// wire regardless of engine completion order.
pub(crate) struct Rail {
    slots: Mutex<BTreeMap<u64, RailSlot>>,
    cv: Condvar,
}

impl Rail {
    fn new() -> Rail {
        Rail { slots: Mutex::new(BTreeMap::new()), cv: Condvar::new() }
    }

    /// Deliver the response for request `seq` (any thread).
    pub fn push(&self, seq: u64, bytes: Vec<u8>) {
        self.slots.lock().unwrap().insert(seq, RailSlot::Full(bytes));
        self.cv.notify_all();
    }

    /// Deliver request `seq`'s response as a chunked stream. The producer
    /// keeps pushing into `stream` after this call; the connection thread
    /// relays each chunk as it lands.
    pub fn push_stream(&self, seq: u64, stream: Arc<ChunkStream>) {
        self.slots.lock().unwrap().insert(seq, RailSlot::Stream(stream));
        self.cv.notify_all();
    }

    /// Block until the response for `seq` is available, then take it.
    fn take(&self, seq: u64) -> RailSlot {
        let mut slots = self.slots.lock().unwrap();
        loop {
            if let Some(slot) = slots.remove(&seq) {
                return slot;
            }
            slots = self.cv.wait(slots).unwrap();
        }
    }
}

/// An incrementally produced response body. The generate pump (running on
/// engine worker threads, one hop per token) pushes pre-framed bytes —
/// chunked head, token-event chunks, terminator — and the connection
/// thread drains them onto the socket in arrival order.
///
/// The `on_client_gone` hook is the cancellation edge: if the socket dies
/// mid-stream (or the server shuts down), the connection thread fires it
/// exactly once. The `/v1/generate` handler wires it to
/// [`GenTicket::cancel`], so an early client disconnect stops the decode
/// loop at the next token boundary instead of generating into the void.
///
/// [`GenTicket::cancel`]: crate::serve::generate::GenTicket::cancel
pub(crate) struct ChunkStream {
    state: Mutex<ChunkState>,
    cv: Condvar,
}

struct ChunkState {
    ready: std::collections::VecDeque<Vec<u8>>,
    closed: bool,
    on_client_gone: Option<Box<dyn FnOnce() + Send>>,
}

/// What the connection thread found when it asked a stream for bytes.
pub(crate) enum StreamStep {
    /// Pre-framed bytes to relay onto the socket.
    Bytes(Vec<u8>),
    /// Nothing yet and the producer is still live — poll tick elapsed.
    Pending,
    /// Producer closed the stream and every chunk has been drained.
    Finished,
}

impl ChunkStream {
    pub fn new(on_client_gone: Box<dyn FnOnce() + Send>) -> Arc<ChunkStream> {
        Arc::new(ChunkStream {
            state: Mutex::new(ChunkState {
                ready: std::collections::VecDeque::new(),
                closed: false,
                on_client_gone: Some(on_client_gone),
            }),
            cv: Condvar::new(),
        })
    }

    /// Producer side: append pre-framed bytes. No-op once closed.
    pub fn push(&self, bytes: Vec<u8>) {
        if bytes.is_empty() {
            return;
        }
        let mut g = self.state.lock().unwrap();
        if g.closed {
            return;
        }
        g.ready.push_back(bytes);
        self.cv.notify_all();
    }

    /// Producer side: no more bytes will follow. Drops the cancel hook —
    /// a finished session has nothing left to cancel.
    pub fn close(&self) {
        let mut g = self.state.lock().unwrap();
        g.closed = true;
        g.on_client_gone = None;
        self.cv.notify_all();
    }

    /// Connection thread: next bytes, waiting up to `poll` — the bound on
    /// how long a streaming response delays a shutdown check.
    fn next_step(&self, poll: Duration) -> StreamStep {
        let mut g = self.state.lock().unwrap();
        if let Some(b) = g.ready.pop_front() {
            return StreamStep::Bytes(b);
        }
        if g.closed {
            return StreamStep::Finished;
        }
        let (mut g, _timeout) = self.cv.wait_timeout(g, poll).unwrap();
        if let Some(b) = g.ready.pop_front() {
            return StreamStep::Bytes(b);
        }
        if g.closed { StreamStep::Finished } else { StreamStep::Pending }
    }

    /// Connection thread: the peer is unreachable; fire the cancel hook
    /// (at most once) so the producer stops decoding.
    fn client_gone(&self) {
        let hook = self.state.lock().unwrap().on_client_gone.take();
        if let Some(hook) = hook {
            hook();
        }
    }
}

/// Builder for [`HttpServer`] — bind address, connection/body caps, and
/// the tenant table.
pub struct HttpServerBuilder {
    engine: Arc<ServeEngine>,
    addr: String,
    max_connections: usize,
    max_body: usize,
    tenants: Vec<(String, String, usize)>,
}

impl HttpServerBuilder {
    /// Listen address (default `127.0.0.1:0` — an OS-assigned loopback
    /// port; read it back with [`HttpServer::addr`]).
    pub fn bind(mut self, addr: &str) -> Self {
        self.addr = addr.to_string();
        self
    }

    /// Connection-pool bound: connections past this many are shed with an
    /// immediate 503 instead of queueing (default 64).
    pub fn max_connections(mut self, n: usize) -> Self {
        self.max_connections = n;
        self
    }

    /// Request-body byte cap; larger declared bodies are refused with 413
    /// before they are buffered (default 8 MiB).
    pub fn max_body(mut self, bytes: usize) -> Self {
        self.max_body = bytes;
        self
    }

    /// Register a tenant: its bearer `token` authenticates `/v1/*` calls,
    /// and `quota` bounds its concurrently in-flight inference requests
    /// (exceeded → 429 before engine admission).
    pub fn tenant(mut self, name: &str, token: &str, quota: usize) -> Self {
        self.tenants.push((name.to_string(), token.to_string(), quota));
        self
    }

    /// Bind the listener and start the accept loop.
    pub fn build(self) -> Result<HttpServer, ServeError> {
        if self.max_connections == 0 {
            return Err(ServeError::InvalidConfig {
                detail: "http server needs max_connections >= 1".to_string(),
            });
        }
        if self.tenants.is_empty() {
            return Err(ServeError::InvalidConfig {
                detail: "http server needs at least one tenant (builder.tenant(name, token, \
                         quota)); an unauthenticated engine on a socket is not a configuration, \
                         it's an incident"
                    .to_string(),
            });
        }
        let listener = TcpListener::bind(&self.addr).map_err(|e| ServeError::InvalidConfig {
            detail: format!("http server could not bind {}: {e}", self.addr),
        })?;
        let addr = listener.local_addr().map_err(|e| ServeError::InvalidConfig {
            detail: format!("http server local_addr failed: {e}"),
        })?;
        let telemetry = self.engine.telemetry_handle();
        let shared = Arc::new(ServerShared {
            engine: self.engine,
            tenants: TenantTable::new(self.tenants),
            telemetry,
            max_body: self.max_body,
            shutdown: AtomicBool::new(false),
        });
        let conns: Arc<Mutex<Vec<thread::JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let shared = Arc::clone(&shared);
            let conns = Arc::clone(&conns);
            let max_connections = self.max_connections;
            thread::Builder::new()
                .name("http-accept".to_string())
                .spawn(move || accept_loop(listener, shared, conns, max_connections))
                .expect("spawn http accept thread")
        };
        Ok(HttpServer { shared, addr, accept: Some(accept), conns })
    }
}

/// The running HTTP front-end. Owns its accept loop and connection
/// threads; [`shutdown`](HttpServer::shutdown) stops them. The engine is
/// shared (`Arc`), not owned — closing the server does not drain the
/// engine.
pub struct HttpServer {
    shared: Arc<ServerShared>,
    addr: SocketAddr,
    accept: Option<thread::JoinHandle<()>>,
    conns: Arc<Mutex<Vec<thread::JoinHandle<()>>>>,
}

impl HttpServer {
    /// Start building a server over `engine`.
    pub fn builder(engine: Arc<ServeEngine>) -> HttpServerBuilder {
        HttpServerBuilder {
            engine,
            addr: "127.0.0.1:0".to_string(),
            max_connections: 64,
            max_body: 8 << 20,
            tenants: Vec::new(),
        }
    }

    /// The bound listen address (resolves `:0` to the real port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, close every connection (in-flight responses get
    /// ~[`READ_POLL`] to flush), and join all server threads.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        if self.shared.shutdown.swap(true, Ordering::SeqCst) {
            return;
        }
        // The accept loop blocks in accept(); poke it awake.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let handles: Vec<_> = self.conns.lock().unwrap().drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.stop();
    }
}

fn accept_loop(
    listener: TcpListener,
    shared: Arc<ServerShared>,
    conns: Arc<Mutex<Vec<thread::JoinHandle<()>>>>,
    max_connections: usize,
) {
    let active = Arc::new(AtomicUsize::new(0));
    for stream in listener.incoming() {
        if shared.shutdown.load(Ordering::SeqCst) {
            break;
        }
        let stream = match stream {
            Ok(s) => s,
            Err(_) => continue,
        };
        shared.telemetry.incr(Counter::HttpConnections);
        let prev = active.fetch_add(1, Ordering::SeqCst);
        if prev >= max_connections {
            active.fetch_sub(1, Ordering::SeqCst);
            // Shed at the pool bound: an explicit, immediate 503 beats an
            // invisible accept-queue stall.
            shed_connection(&shared, stream);
            continue;
        }
        let handle = {
            let shared = Arc::clone(&shared);
            let active = Arc::clone(&active);
            thread::Builder::new()
                .name("http-conn".to_string())
                .spawn(move || {
                    connection_loop(shared, stream);
                    active.fetch_sub(1, Ordering::SeqCst);
                })
                .expect("spawn http connection thread")
        };
        let mut guard = conns.lock().unwrap();
        guard.retain(|h| !h.is_finished()); // reap exited connections
        guard.push(handle);
    }
}

fn shed_connection(shared: &ServerShared, mut stream: TcpStream) {
    let body = error_body("overloaded", "connection pool is full; retry");
    let bytes = respond(&shared.telemetry, 503, &body, false);
    let _ = stream.write_all(&bytes);
}

fn connection_loop(shared: Arc<ServerShared>, mut stream: TcpStream) {
    let _ = stream.set_read_timeout(Some(READ_POLL));
    let _ = stream.set_nodelay(true);
    let rail = Arc::new(Rail::new());
    let mut parser = wire::RequestParser::new(shared.max_body);
    let mut seq: u64 = 0; // next request sequence to assign
    let mut written: u64 = 0; // next response sequence to write
    let mut close_after: Option<u64> = None; // last seq before close
    let mut readbuf = [0u8; 16 * 1024];
    loop {
        // Dispatch every complete request already buffered. All of them
        // enter the engine before we block on the first response — that
        // is the pipelining win.
        while close_after.is_none() {
            match parser.next() {
                Ok(Some(req)) => {
                    if !req.keep_alive {
                        close_after = Some(seq);
                    }
                    handlers::handle(&shared, req, &rail, seq);
                    seq += 1;
                }
                Ok(None) => break,
                Err(we) => {
                    // Protocol error: the byte stream has no trustworthy
                    // resync point. Answer and close.
                    let body = error_body(we.code(), &we.to_string());
                    rail.push(seq, respond(&shared.telemetry, we.status(), &body, false));
                    close_after = Some(seq);
                    seq += 1;
                }
            }
        }
        // Flush responses strictly in order; completion callbacks fill
        // the rail from engine worker threads.
        while written < seq {
            match rail.take(written) {
                RailSlot::Full(bytes) => {
                    if stream.write_all(&bytes).is_err() {
                        return;
                    }
                }
                RailSlot::Stream(chunks) => loop {
                    match chunks.next_step(READ_POLL) {
                        StreamStep::Bytes(b) => {
                            if stream.write_all(&b).is_err() {
                                // Peer vanished mid-stream: cancel the
                                // generation instead of decoding into
                                // a dead socket.
                                chunks.client_gone();
                                return;
                            }
                        }
                        StreamStep::Finished => break,
                        StreamStep::Pending => {
                            if shared.shutdown.load(Ordering::SeqCst) {
                                chunks.client_gone();
                                return;
                            }
                        }
                    }
                },
            }
            written += 1;
        }
        if let Some(last) = close_after {
            if written > last {
                return;
            }
        }
        if shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
        match stream.read(&mut readbuf) {
            Ok(0) => return, // peer closed
            Ok(n) => parser.feed(&readbuf[..n]),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue; // read-poll tick: re-check shutdown
            }
            Err(_) => return,
        }
    }
}

/// Build the `{code, message}` JSON error body — the wire error contract.
pub(crate) fn error_body(code: &str, message: &str) -> Json {
    Json::from_pairs(vec![("code", Json::from(code)), ("message", Json::from(message))])
}

/// Map a typed engine error onto the wire: status from
/// [`ServeError::http_status`], body `{code, message}` from
/// [`ServeError::code`] / `Display`.
pub(crate) fn error_response(tel: &Telemetry, e: &ServeError, keep_alive: bool) -> Vec<u8> {
    respond(tel, e.http_status(), &error_body(e.code(), &e.to_string()), keep_alive)
}

/// Serialize a JSON response and tick the per-status-class counters.
pub(crate) fn respond(tel: &Telemetry, status: u16, body: &Json, keep_alive: bool) -> Vec<u8> {
    respond_raw(tel, status, "application/json", body.to_string_compact().as_bytes(), keep_alive)
}

/// Serialize a response with an explicit content type (the `/metrics`
/// text path) and tick the per-status-class counters.
pub(crate) fn respond_raw(
    tel: &Telemetry,
    status: u16,
    content_type: &str,
    body: &[u8],
    keep_alive: bool,
) -> Vec<u8> {
    match status / 100 {
        2 => tel.incr(Counter::HttpOk),
        4 => tel.incr(Counter::HttpClientErrors),
        5 => tel.incr(Counter::HttpServerErrors),
        _ => {}
    }
    wire::write_response(status, content_type, body, keep_alive)
}

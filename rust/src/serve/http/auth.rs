//! Tenant authentication and per-tenant in-flight quotas.
//!
//! Every `/v1/*` endpoint requires a per-tenant bearer token
//! (`Authorization: Bearer <token>`), configured at server build time
//! ([`HttpServerBuilder::tenant`]). Each tenant carries an **in-flight
//! quota**: the number of inference requests it may have unresolved in
//! the engine at once. The quota is charged BEFORE engine admission and
//! released when the request's completion callback fires — so a tenant
//! that floods the server gets typed `429 quota-exceeded` responses
//! without its traffic ever touching the engine's shared admission path,
//! and without disturbing other tenants' share of `max_pending`.
//!
//! Admin calls (adapter lifecycle, stats) authenticate but do not charge
//! the quota: they are synchronous, cheap, and must keep working for a
//! tenant that has saturated its inference quota (how else would it
//! unregister the adapter that's flooding?).
//!
//! `GET /metrics` is deliberately UNAUTHENTICATED — it is the scrape
//! endpoint for infrastructure Prometheus, carries no tenant data beyond
//! aggregate counters, and scrapers don't hold tenant tokens. Bind the
//! listener accordingly.
//!
//! [`HttpServerBuilder::tenant`]: super::HttpServerBuilder::tenant

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// One configured tenant: its bearer token and in-flight quota.
pub(crate) struct Tenant {
    pub name: String,
    token: String,
    quota: usize,
    in_flight: AtomicUsize,
}

impl Tenant {
    /// Charge one in-flight slot; `None` when the tenant is at quota.
    /// The returned guard releases the slot on drop (the completion
    /// callback holds it until the engine answers).
    pub fn try_acquire(self: &Arc<Tenant>) -> Option<QuotaGuard> {
        let prev = self.in_flight.fetch_add(1, Ordering::SeqCst);
        if prev >= self.quota {
            self.in_flight.fetch_sub(1, Ordering::SeqCst);
            return None;
        }
        Some(QuotaGuard { tenant: Arc::clone(self) })
    }

    #[cfg(test)]
    fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::SeqCst)
    }
}

/// An acquired in-flight slot; releases on drop.
pub(crate) struct QuotaGuard {
    tenant: Arc<Tenant>,
}

impl Drop for QuotaGuard {
    fn drop(&mut self) {
        self.tenant.in_flight.fetch_sub(1, Ordering::SeqCst);
    }
}

/// The immutable tenant table, built once by the server builder.
pub(crate) struct TenantTable {
    tenants: Vec<Arc<Tenant>>,
}

impl TenantTable {
    pub fn new(entries: Vec<(String, String, usize)>) -> TenantTable {
        let tenants = entries
            .into_iter()
            .map(|(name, token, quota)| {
                Arc::new(Tenant { name, token, quota, in_flight: AtomicUsize::new(0) })
            })
            .collect();
        TenantTable { tenants }
    }

    pub fn is_empty(&self) -> bool {
        self.tenants.is_empty()
    }

    /// Resolve a bearer token to its tenant. Linear scan: tenant counts
    /// are small (tens), and the scan compares full tokens — no prefix
    /// shortcuts.
    pub fn authenticate(&self, bearer: Option<&str>) -> Option<Arc<Tenant>> {
        let token = bearer?;
        self.tenants.iter().find(|t| t.token == token).cloned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> TenantTable {
        TenantTable::new(vec![
            ("alice".into(), "tok-alice".into(), 2),
            ("bob".into(), "tok-bob".into(), 0),
        ])
    }

    #[test]
    fn tokens_resolve_to_their_tenant() {
        let t = table();
        assert_eq!(t.authenticate(Some("tok-alice")).unwrap().name, "alice");
        assert!(t.authenticate(Some("tok-eve")).is_none());
        assert!(t.authenticate(None).is_none());
    }

    #[test]
    fn quota_charges_and_releases() {
        let t = table();
        let alice = t.authenticate(Some("tok-alice")).unwrap();
        let g1 = alice.try_acquire().unwrap();
        let g2 = alice.try_acquire().unwrap();
        assert!(alice.try_acquire().is_none(), "at quota");
        drop(g1);
        let g3 = alice.try_acquire().expect("released slot is reusable");
        drop(g2);
        drop(g3);
        assert_eq!(alice.in_flight(), 0);
    }

    #[test]
    fn zero_quota_rejects_everything() {
        let t = table();
        let bob = t.authenticate(Some("tok-bob")).unwrap();
        assert!(bob.try_acquire().is_none());
        assert_eq!(bob.in_flight(), 0, "failed acquire leaves no residue");
    }
}

//! Lazy scan-for-path JSON field extraction — the hot-path decoder.
//!
//! The inference endpoints all take one small, flat shape:
//! `{"layer": "...", "adapter": "...", "x": [f64...]}` (and the
//! route/steps variants). Building a full `util::json::Json` tree for
//! that — a `BTreeMap`, a boxed node per array element, every number
//! round-tripped through an enum — costs far more than the extraction
//! needs. This scanner instead makes ONE forward pass per field: walk the
//! top-level object's keys, skip values that don't match (string skip,
//! number skip, bracket-depth skip for nested values — no tree, no
//! allocation), and parse only the matching value into its typed form
//! (the "lazy scanning: scan bytes → find path → extract" idea recorded
//! in ROADMAP's mik-sdk note).
//!
//! Admin bodies (adapter registration, with nested per-layer objects and
//! two matrices each) stay on the full `util::json` parser — they are
//! rare, structurally deep, and not worth a hand-rolled path.
//!
//! Strictness: the scanner validates everything it TOUCHES (the key
//! syntax, the matched value, the object's comma structure) and
//! bracket-skips what it doesn't. A body this front-end accepts is valid
//! enough that the same extraction from a tree parse would agree;
//! `rust/tests/http_serve.rs` cross-checks exactly that.

use std::fmt;

/// A malformed body, as far as the scanner walked it.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ScanError {
    /// The body is not a JSON object at the top level.
    NotAnObject,
    /// Structural JSON error at byte `at`.
    Malformed { at: usize, what: &'static str },
    /// The matched field exists but has the wrong type.
    WrongType { key: &'static str, want: &'static str },
}

impl fmt::Display for ScanError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ScanError::NotAnObject => f.write_str("body must be a JSON object"),
            ScanError::Malformed { at, what } => {
                write!(f, "malformed JSON at byte {at}: {what}")
            }
            ScanError::WrongType { key, want } => {
                write!(f, "field '{key}' must be {want}")
            }
        }
    }
}

/// One scan pass over `body` for top-level key `key`: `Ok(None)` when the
/// key is absent, the raw value slice + offset when found.
fn find_value<'a>(body: &'a [u8], key: &str) -> Result<Option<(&'a [u8], usize)>, ScanError> {
    let mut s = Cursor { b: body, i: 0 };
    s.skip_ws();
    if s.peek() != Some(b'{') {
        return Err(ScanError::NotAnObject);
    }
    s.i += 1;
    s.skip_ws();
    if s.peek() == Some(b'}') {
        return Ok(None);
    }
    loop {
        s.skip_ws();
        let k = s.parse_string_raw()?;
        s.skip_ws();
        s.expect(b':')?;
        s.skip_ws();
        let start = s.i;
        if key_matches(k, key) {
            s.skip_value()?;
            return Ok(Some((&body[start..s.i], start)));
        }
        s.skip_value()?;
        s.skip_ws();
        match s.peek() {
            Some(b',') => s.i += 1,
            Some(b'}') => return Ok(None),
            _ => return Err(s.malformed("expected ',' or '}' after a value")),
        }
    }
}

/// Key comparison on the RAW (still-escaped) key bytes. Endpoint keys are
/// plain ASCII identifiers, so an escaped spelling of one (`"l..."`)
/// simply doesn't match — same outcome as an unknown key.
fn key_matches(raw: &[u8], key: &str) -> bool {
    raw == key.as_bytes()
}

/// Extract an optional string field (`Ok(None)` when absent or `null`).
pub fn str_field(body: &[u8], key: &'static str) -> Result<Option<String>, ScanError> {
    let (v, at) = match find_value(body, key)? {
        None => return Ok(None),
        Some(v) => v,
    };
    if v == b"null" {
        return Ok(None);
    }
    let mut s = Cursor { b: v, i: 0 };
    if s.peek() != Some(b'"') {
        return Err(ScanError::WrongType { key, want: "a string" });
    }
    let out = s.parse_string()?;
    debug_assert!(at < body.len());
    Ok(Some(out))
}

/// Extract a required array-of-numbers field.
pub fn f64_array_field(body: &[u8], key: &'static str) -> Result<Option<Vec<f64>>, ScanError> {
    let v = match find_value(body, key)? {
        None => return Ok(None),
        Some((v, _)) => v,
    };
    let mut s = Cursor { b: v, i: 0 };
    if s.peek() != Some(b'[') {
        return Err(ScanError::WrongType { key, want: "an array of numbers" });
    }
    s.i += 1;
    let mut out = Vec::new();
    s.skip_ws();
    if s.peek() == Some(b']') {
        return Ok(Some(out));
    }
    loop {
        s.skip_ws();
        out.push(
            s.parse_number()
                .map_err(|_| ScanError::WrongType { key, want: "an array of numbers" })?,
        );
        s.skip_ws();
        match s.peek() {
            Some(b',') => s.i += 1,
            Some(b']') => return Ok(Some(out)),
            _ => return Err(s.malformed("expected ',' or ']' in array")),
        }
    }
}

/// Extract an array-of-strings field (route names).
pub fn str_array_field(body: &[u8], key: &'static str) -> Result<Option<Vec<String>>, ScanError> {
    let v = match find_value(body, key)? {
        None => return Ok(None),
        Some((v, _)) => v,
    };
    let mut s = Cursor { b: v, i: 0 };
    if s.peek() != Some(b'[') {
        return Err(ScanError::WrongType { key, want: "an array of strings" });
    }
    s.i += 1;
    let mut out = Vec::new();
    s.skip_ws();
    if s.peek() == Some(b']') {
        return Ok(Some(out));
    }
    loop {
        s.skip_ws();
        if s.peek() != Some(b'"') {
            return Err(ScanError::WrongType { key, want: "an array of strings" });
        }
        out.push(s.parse_string()?);
        s.skip_ws();
        match s.peek() {
            Some(b',') => s.i += 1,
            Some(b']') => return Ok(Some(out)),
            _ => return Err(s.malformed("expected ',' or ']' in array")),
        }
    }
}

/// Extract a non-negative integer field.
pub fn u64_field(body: &[u8], key: &'static str) -> Result<Option<u64>, ScanError> {
    let v = match find_value(body, key)? {
        None => return Ok(None),
        Some((v, _)) => v,
    };
    let text = std::str::from_utf8(v)
        .map_err(|_| ScanError::WrongType { key, want: "a non-negative integer" })?;
    text.trim()
        .parse::<u64>()
        .map(Some)
        .map_err(|_| ScanError::WrongType { key, want: "a non-negative integer" })
}

struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn malformed(&self, what: &'static str) -> ScanError {
        ScanError::Malformed { at: self.i, what }
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ScanError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.malformed("unexpected byte"))
        }
    }

    /// Consume a string literal, returning its raw (still-escaped)
    /// contents — enough to match keys without allocating.
    fn parse_string_raw(&mut self) -> Result<&'a [u8], ScanError> {
        self.expect(b'"').map_err(|_| self.malformed("expected a string key"))?;
        let start = self.i;
        loop {
            match self.peek() {
                None => return Err(self.malformed("unterminated string")),
                Some(b'"') => {
                    let raw = &self.b[start..self.i];
                    self.i += 1;
                    return Ok(raw);
                }
                Some(b'\\') => {
                    self.i += 2; // skip the escape pair (\" included)
                }
                Some(_) => self.i += 1,
            }
        }
    }

    /// Consume a string literal and unescape it.
    fn parse_string(&mut self) -> Result<String, ScanError> {
        let at = self.i;
        let raw = self.parse_string_raw()?;
        let mut out = String::with_capacity(raw.len());
        let mut it = raw.iter().copied();
        while let Some(b) = it.next() {
            if b != b'\\' {
                out.push(b as char);
                continue;
            }
            match it.next() {
                Some(b'"') => out.push('"'),
                Some(b'\\') => out.push('\\'),
                Some(b'/') => out.push('/'),
                Some(b'n') => out.push('\n'),
                Some(b't') => out.push('\t'),
                Some(b'r') => out.push('\r'),
                Some(b'u') => {
                    let hex: String = (0..4).filter_map(|_| it.next()).map(|c| c as char).collect();
                    let cp = u32::from_str_radix(&hex, 16)
                        .map_err(|_| ScanError::Malformed { at, what: "bad \\u escape" })?;
                    out.push(
                        char::from_u32(cp)
                            .ok_or(ScanError::Malformed { at, what: "bad \\u escape" })?,
                    );
                }
                _ => return Err(ScanError::Malformed { at, what: "bad escape" }),
            }
        }
        Ok(out)
    }

    fn parse_number(&mut self) -> Result<f64, ScanError> {
        let start = self.i;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        ) {
            self.i += 1;
        }
        if self.i == start {
            return Err(self.malformed("expected a number"));
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|t| t.parse::<f64>().ok())
            .filter(|v| v.is_finite())
            .ok_or(ScanError::Malformed { at: start, what: "invalid number" })
    }

    /// Skip one JSON value of any type without materializing it —
    /// bracket-depth counting for containers, literal consumption for
    /// scalars.
    fn skip_value(&mut self) -> Result<(), ScanError> {
        match self.peek() {
            Some(b'"') => {
                self.parse_string_raw()?;
                Ok(())
            }
            Some(b'{' | b'[') => {
                let mut depth = 0usize;
                loop {
                    match self.peek() {
                        None => return Err(self.malformed("unterminated container")),
                        Some(b'"') => {
                            self.parse_string_raw()?;
                        }
                        Some(b'{' | b'[') => {
                            depth += 1;
                            self.i += 1;
                        }
                        Some(b'}' | b']') => {
                            depth -= 1;
                            self.i += 1;
                            if depth == 0 {
                                return Ok(());
                            }
                        }
                        Some(_) => self.i += 1,
                    }
                }
            }
            Some(b't') => self.consume_literal(b"true"),
            Some(b'f') => self.consume_literal(b"false"),
            Some(b'n') => self.consume_literal(b"null"),
            Some(_) => self.parse_number().map(|_| ()),
            None => Err(self.malformed("expected a value")),
        }
    }

    fn consume_literal(&mut self, lit: &[u8]) -> Result<(), ScanError> {
        if self.b[self.i..].starts_with(lit) {
            self.i += lit.len();
            Ok(())
        } else {
            Err(self.malformed("bad literal"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const BODY: &[u8] =
        br#"{"layer": "blk0.wq", "adapter": null, "x": [1.5, -2.0, 3e-1], "steps": 4}"#;

    #[test]
    fn extracts_each_field_in_one_pass() {
        assert_eq!(str_field(BODY, "layer").unwrap().unwrap(), "blk0.wq");
        assert_eq!(str_field(BODY, "adapter").unwrap(), None, "null reads as absent");
        assert_eq!(f64_array_field(BODY, "x").unwrap().unwrap(), vec![1.5, -2.0, 0.3]);
        assert_eq!(u64_field(BODY, "steps").unwrap(), Some(4));
        assert_eq!(str_field(BODY, "missing").unwrap(), None);
    }

    #[test]
    fn skips_unmatched_values_without_parsing_them() {
        // The scanner must hop over nested containers and strings with
        // escaped quotes to reach a later key.
        let body = br#"{"noise": {"deep": [1, {"k": "\" } ]"}]}, "x": [7]}"#;
        assert_eq!(f64_array_field(body, "x").unwrap().unwrap(), vec![7.0]);
    }

    #[test]
    fn route_arrays_and_escapes() {
        let body = br#"{"route": ["a", "b\nc"], "x": []}"#;
        let names = str_array_field(body, "route").unwrap().unwrap();
        assert_eq!(names, vec!["a".to_string(), "b\nc".to_string()]);
        assert_eq!(f64_array_field(body, "x").unwrap().unwrap(), Vec::<f64>::new());
    }

    #[test]
    fn malformed_bodies_are_typed_errors() {
        assert_eq!(str_field(b"[1,2]", "k").unwrap_err(), ScanError::NotAnObject);
        assert!(matches!(
            str_field(br#"{"k" 1}"#, "k").unwrap_err(),
            ScanError::Malformed { .. }
        ));
        assert!(matches!(
            f64_array_field(br#"{"x": "nope"}"#, "x").unwrap_err(),
            ScanError::WrongType { key: "x", .. }
        ));
        assert!(matches!(
            f64_array_field(br#"{"x": [1, "two"]}"#, "x").unwrap_err(),
            ScanError::WrongType { .. }
        ));
        assert!(matches!(
            str_field(br#"{"k": "unterminated"#, "k").unwrap_err(),
            ScanError::Malformed { .. }
        ));
        // Non-finite numeric spellings are rejected, not smuggled in.
        assert!(f64_array_field(br#"{"x": [1e999]}"#, "x").is_err());
    }

    #[test]
    fn agrees_with_the_tree_parser_on_accepted_bodies() {
        let tree = crate::util::json::parse(std::str::from_utf8(BODY).unwrap()).unwrap();
        let x_tree: Vec<f64> =
            tree.get("x").unwrap().as_arr().unwrap().iter().map(|v| v.as_f64().unwrap()).collect();
        assert_eq!(f64_array_field(BODY, "x").unwrap().unwrap(), x_tree);
        assert_eq!(
            str_field(BODY, "layer").unwrap().unwrap(),
            tree.get("layer").unwrap().as_str().unwrap()
        );
    }
}

//! The HTTP/1.1 wire layer: an incremental request parser and the
//! response writer — `std::net` only, no dependencies (the workspace is
//! offline by design).
//!
//! The parser is a push-style state accumulator: [`RequestParser::feed`]
//! appends whatever bytes the socket produced — a byte, a torn header, six
//! pipelined requests — and [`RequestParser::next`] yields complete
//! requests one at a time, returning `Ok(None)` whenever the buffer holds
//! only a partial request. The result is byte-boundary independence: any
//! split of the same byte stream parses to the same request sequence
//! (`rust/tests/http_serve.rs` proves it by feeding canned requests split
//! at EVERY boundary).
//!
//! Scope, on purpose: `Content-Length` bodies only (`Transfer-Encoding`
//! is refused with 501 — the engine's request shapes are all
//! known-length), HTTP/1.0 and 1.1, keep-alive + pipelining, and hard
//! limits on request-line length, header count, header bytes, and body
//! size so a malicious peer cannot balloon the connection buffer.
//!
//! Only the headers the front-end consumes are retained (`Content-Length`,
//! `Connection`, `Authorization`); everything else is validated for shape
//! and dropped — the parser allocates per REQUEST, not per header.

use std::fmt;

/// Hard cap on the request line (`METHOD SP target SP version`).
pub const MAX_REQUEST_LINE: usize = 2048;
/// Hard cap on the number of header lines.
pub const MAX_HEADERS: usize = 64;
/// Hard cap on the total header-section bytes (request line included).
pub const MAX_HEAD_BYTES: usize = 8192;

/// A protocol-level parse failure. Fatal for its connection: after a
/// malformed request the byte stream has no trustworthy resynchronization
/// point, so the front-end writes the mapped error response and closes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WireError {
    /// The request line is not `METHOD SP target SP HTTP/1.x`.
    BadRequestLine,
    /// The version is neither `HTTP/1.0` nor `HTTP/1.1`.
    BadVersion,
    /// A header line has no colon, an empty name, or whitespace in the
    /// name.
    BadHeader,
    /// More than [`MAX_HEADERS`] header lines.
    TooManyHeaders,
    /// The header section exceeds [`MAX_HEAD_BYTES`] (or the request line
    /// exceeds [`MAX_REQUEST_LINE`]) before terminating.
    HeadersTooLarge,
    /// `Content-Length` is not a plain decimal, or conflicting duplicates.
    BadContentLength,
    /// The declared body exceeds the server's body cap.
    BodyTooLarge { limit: usize },
    /// `Transfer-Encoding` (chunked etc.) is not supported.
    UnsupportedEncoding,
}

impl WireError {
    /// HTTP status for the mapped error response.
    pub fn status(&self) -> u16 {
        match self {
            WireError::BadRequestLine
            | WireError::BadHeader
            | WireError::BadContentLength => 400,
            WireError::BadVersion => 505,
            WireError::TooManyHeaders | WireError::HeadersTooLarge => 431,
            WireError::BodyTooLarge { .. } => 413,
            WireError::UnsupportedEncoding => 501,
        }
    }

    /// Stable machine-readable code for the JSON error body (the parser's
    /// side of the wire contract `ServeError::code` anchors).
    pub fn code(&self) -> &'static str {
        match self {
            WireError::BadRequestLine => "bad-request-line",
            WireError::BadVersion => "bad-version",
            WireError::BadHeader => "bad-header",
            WireError::TooManyHeaders => "too-many-headers",
            WireError::HeadersTooLarge => "headers-too-large",
            WireError::BadContentLength => "bad-content-length",
            WireError::BodyTooLarge { .. } => "body-too-large",
            WireError::UnsupportedEncoding => "unsupported-encoding",
        }
    }
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::BadRequestLine => f.write_str("malformed request line"),
            WireError::BadVersion => f.write_str("only HTTP/1.0 and HTTP/1.1 are supported"),
            WireError::BadHeader => f.write_str("malformed header line"),
            WireError::TooManyHeaders => {
                write!(f, "more than {MAX_HEADERS} header lines")
            }
            WireError::HeadersTooLarge => {
                write!(f, "header section exceeds {MAX_HEAD_BYTES} bytes")
            }
            WireError::BadContentLength => f.write_str("invalid Content-Length"),
            WireError::BodyTooLarge { limit } => {
                write!(f, "request body exceeds the {limit}-byte limit")
            }
            WireError::UnsupportedEncoding => {
                f.write_str("Transfer-Encoding is not supported; send a Content-Length body")
            }
        }
    }
}

/// One parsed request: the routing fields plus the raw body. Headers the
/// front-end does not consume are validated and dropped.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    /// The request target as sent (path; query strings are not used by
    /// any endpoint and are kept verbatim in the path string).
    pub target: String,
    /// Whether the connection stays open after this exchange (HTTP/1.1
    /// default, overridable by `Connection:`; HTTP/1.0 defaults closed).
    pub keep_alive: bool,
    /// The `Bearer` token from `Authorization`, if one was sent.
    pub bearer: Option<String>,
    pub body: Vec<u8>,
}

/// Incremental HTTP/1.1 request parser — see the module docs for the
/// feed/next contract and limits.
pub struct RequestParser {
    buf: Vec<u8>,
    /// Consumed prefix of `buf` (drained lazily to keep feed() cheap).
    pos: usize,
    max_body: usize,
}

impl RequestParser {
    pub fn new(max_body: usize) -> RequestParser {
        RequestParser { buf: Vec::new(), pos: 0, max_body }
    }

    /// Append bytes read from the socket.
    pub fn feed(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Unconsumed bytes currently buffered.
    pub fn buffered(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Yield the next complete request, `Ok(None)` if the buffer holds
    /// only a partial one (feed more and retry), or the protocol error
    /// that makes this connection unrecoverable.
    pub fn next(&mut self) -> Result<Option<Request>, WireError> {
        let avail = &self.buf[self.pos..];
        // Skip blank lines between pipelined requests (robustness: some
        // clients terminate each request with an extra CRLF).
        let lead = avail.iter().take_while(|&&b| b == b'\r' || b == b'\n').count();
        let avail = &avail[lead..];
        if avail.is_empty() {
            self.pos += lead;
            return Ok(None);
        }
        let head_end = match find_head_end(avail) {
            Some(n) => n,
            None => {
                if avail.len() > MAX_HEAD_BYTES {
                    return Err(WireError::HeadersTooLarge);
                }
                return Ok(None);
            }
        };
        if head_end > MAX_HEAD_BYTES {
            return Err(WireError::HeadersTooLarge);
        }
        let head = &avail[..head_end];
        let parsed = parse_head(head)?;
        if parsed.content_length > self.max_body {
            return Err(WireError::BodyTooLarge { limit: self.max_body });
        }
        let body_start = head_end + 4; // past CRLFCRLF
        let total = body_start + parsed.content_length;
        if avail.len() < total {
            return Ok(None); // body still arriving
        }
        let body = avail[body_start..total].to_vec();
        self.pos += lead + total;
        // Compact once the consumed prefix dominates, so a long-lived
        // keep-alive connection cannot grow the buffer without bound.
        if self.pos > 16 * 1024 || self.pos == self.buf.len() {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
        Ok(Some(Request {
            method: parsed.method,
            target: parsed.target,
            keep_alive: parsed.keep_alive,
            bearer: parsed.bearer,
            body,
        }))
    }
}

/// Byte offset of the `\r\n\r\n` head terminator, if present.
fn find_head_end(b: &[u8]) -> Option<usize> {
    b.windows(4).position(|w| w == b"\r\n\r\n")
}

struct Head {
    method: String,
    target: String,
    keep_alive: bool,
    bearer: Option<String>,
    content_length: usize,
}

fn parse_head(head: &[u8]) -> Result<Head, WireError> {
    let mut lines = head.split(|&b| b == b'\n').map(|l| l.strip_suffix(b"\r").unwrap_or(l));
    let request_line = lines.next().ok_or(WireError::BadRequestLine)?;
    if request_line.len() > MAX_REQUEST_LINE {
        return Err(WireError::HeadersTooLarge);
    }
    let line = std::str::from_utf8(request_line).map_err(|_| WireError::BadRequestLine)?;
    let mut parts = line.split(' ');
    let (method, target, version) = match (parts.next(), parts.next(), parts.next(), parts.next())
    {
        (Some(m), Some(t), Some(v), None) if !m.is_empty() && !t.is_empty() => (m, t, v),
        _ => return Err(WireError::BadRequestLine),
    };
    if !method.bytes().all(|b| b.is_ascii_uppercase()) {
        return Err(WireError::BadRequestLine);
    }
    let http11 = match version {
        "HTTP/1.1" => true,
        "HTTP/1.0" => false,
        _ => return Err(WireError::BadVersion),
    };

    let mut keep_alive = http11; // 1.1 defaults open, 1.0 defaults closed
    let mut bearer = None;
    let mut content_length: Option<usize> = None;
    let mut n_headers = 0usize;
    for raw in lines {
        if raw.is_empty() {
            continue; // the terminator's empty line
        }
        n_headers += 1;
        if n_headers > MAX_HEADERS {
            return Err(WireError::TooManyHeaders);
        }
        let colon = raw.iter().position(|&b| b == b':').ok_or(WireError::BadHeader)?;
        let (name, value) = raw.split_at(colon);
        if name.is_empty() || name.iter().any(|b| b.is_ascii_whitespace()) {
            return Err(WireError::BadHeader);
        }
        let name = std::str::from_utf8(name).map_err(|_| WireError::BadHeader)?;
        let value = std::str::from_utf8(&value[1..]).map_err(|_| WireError::BadHeader)?.trim();
        if name.eq_ignore_ascii_case("content-length") {
            if value.is_empty() || !value.bytes().all(|b| b.is_ascii_digit()) {
                return Err(WireError::BadContentLength);
            }
            let n: usize = value.parse().map_err(|_| WireError::BadContentLength)?;
            match content_length {
                Some(prev) if prev != n => return Err(WireError::BadContentLength),
                _ => content_length = Some(n),
            }
        } else if name.eq_ignore_ascii_case("transfer-encoding") {
            return Err(WireError::UnsupportedEncoding);
        } else if name.eq_ignore_ascii_case("connection") {
            if value.eq_ignore_ascii_case("close") {
                keep_alive = false;
            } else if value.eq_ignore_ascii_case("keep-alive") {
                keep_alive = true;
            }
        } else if name.eq_ignore_ascii_case("authorization") {
            if let Some(tok) = value.strip_prefix("Bearer ") {
                bearer = Some(tok.trim().to_string());
            }
        }
    }
    Ok(Head {
        method: method.to_string(),
        target: target.to_string(),
        keep_alive,
        bearer,
        content_length: content_length.unwrap_or(0),
    })
}

/// Canonical reason phrase for every status the front-end emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        401 => "Unauthorized",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        505 => "HTTP Version Not Supported",
        _ => "Unknown",
    }
}

/// Serialize one response: status line, `Content-Length`, `Content-Type`,
/// `Connection`, body.
pub fn write_response(
    status: u16,
    content_type: &str,
    body: &[u8],
    keep_alive: bool,
) -> Vec<u8> {
    let conn = if keep_alive { "keep-alive" } else { "close" };
    let head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n",
        status,
        reason(status),
        content_type,
        body.len(),
        conn
    );
    let mut out = Vec::with_capacity(head.len() + body.len());
    out.extend_from_slice(head.as_bytes());
    out.extend_from_slice(body);
    out
}

/// Head of a `Transfer-Encoding: chunked` response — the streaming reply
/// framing (`/v1/generate` with `"stream": true`). The body follows as
/// [`write_chunk`] frames terminated by [`write_last_chunk`]; keep-alive
/// survives a chunked response because the zero-length chunk marks the
/// end-of-body boundary the `Content-Length` header normally provides.
///
/// NOTE the asymmetry with the parser: chunked *requests* stay refused
/// ([`WireError::UnsupportedEncoding`]) — every request body the engine
/// accepts is known-length — only responses stream.
pub fn write_chunked_head(status: u16, content_type: &str, keep_alive: bool) -> Vec<u8> {
    let conn = if keep_alive { "keep-alive" } else { "close" };
    format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nTransfer-Encoding: chunked\r\nConnection: {}\r\n\r\n",
        status,
        reason(status),
        content_type,
        conn
    )
    .into_bytes()
}

/// One chunk frame: `{len:x}\r\n` + data + `\r\n`. Empty data returns no
/// bytes — a zero-length chunk is the TERMINATOR ([`write_last_chunk`]),
/// so emitting one mid-stream would truncate the response.
pub fn write_chunk(data: &[u8]) -> Vec<u8> {
    if data.is_empty() {
        return Vec::new();
    }
    let head = format!("{:x}\r\n", data.len());
    let mut out = Vec::with_capacity(head.len() + data.len() + 2);
    out.extend_from_slice(head.as_bytes());
    out.extend_from_slice(data);
    out.extend_from_slice(b"\r\n");
    out
}

/// The chunked-body terminator: the zero-length chunk (no trailers).
pub fn write_last_chunk() -> Vec<u8> {
    b"0\r\n\r\n".to_vec()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_one(raw: &[u8]) -> Result<Option<Request>, WireError> {
        let mut p = RequestParser::new(1 << 20);
        p.feed(raw);
        p.next()
    }

    #[test]
    fn parses_a_plain_request_with_body() {
        let raw = b"POST /v1/submit HTTP/1.1\r\nAuthorization: Bearer tok-1\r\n\
                    Content-Length: 4\r\n\r\nabcd";
        let req = parse_one(raw).unwrap().unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.target, "/v1/submit");
        assert!(req.keep_alive, "HTTP/1.1 defaults to keep-alive");
        assert_eq!(req.bearer.as_deref(), Some("tok-1"));
        assert_eq!(req.body, b"abcd");
    }

    #[test]
    fn torn_input_resumes_wherever_the_split_fell() {
        let raw: &[u8] = b"GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n";
        for cut in 0..=raw.len() {
            let mut p = RequestParser::new(1024);
            p.feed(&raw[..cut]);
            let first = p.next().unwrap();
            if cut < raw.len() {
                assert!(first.is_none(), "cut={cut}: incomplete must yield None");
            }
            p.feed(&raw[cut..]);
            let req = p.next().unwrap().expect("complete after the rest arrives");
            assert_eq!(req.method, "GET");
            assert_eq!(req.target, "/metrics");
            assert!(req.body.is_empty());
        }
    }

    #[test]
    fn pipelined_requests_come_out_in_order() {
        let mut p = RequestParser::new(1024);
        p.feed(b"POST /a HTTP/1.1\r\nContent-Length: 2\r\n\r\nhi");
        p.feed(b"GET /b HTTP/1.1\r\nConnection: close\r\n\r\n");
        let a = p.next().unwrap().unwrap();
        assert_eq!((a.target.as_str(), a.body.as_slice()), ("/a", &b"hi"[..]));
        let b = p.next().unwrap().unwrap();
        assert_eq!(b.target, "/b");
        assert!(!b.keep_alive);
        assert!(p.next().unwrap().is_none());
    }

    #[test]
    fn protocol_errors_are_typed() {
        assert_eq!(parse_one(b"NOT A REQUEST\r\n\r\n").unwrap_err(), WireError::BadRequestLine);
        assert_eq!(parse_one(b"GET / HTTP/2.0\r\n\r\n").unwrap_err(), WireError::BadVersion);
        assert_eq!(
            parse_one(b"GET / HTTP/1.1\r\nbad line\r\n\r\n").unwrap_err(),
            WireError::BadHeader
        );
        assert_eq!(
            parse_one(b"GET / HTTP/1.1\r\nContent-Length: nope\r\n\r\n").unwrap_err(),
            WireError::BadContentLength
        );
        assert_eq!(
            parse_one(b"GET / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n").unwrap_err(),
            WireError::UnsupportedEncoding
        );
    }

    #[test]
    fn oversized_bodies_are_refused_before_they_arrive() {
        let mut p = RequestParser::new(8);
        p.feed(b"POST / HTTP/1.1\r\nContent-Length: 9\r\n\r\n");
        // Refused from the declared length alone — no need to buffer 9 bytes.
        assert_eq!(p.next().unwrap_err(), WireError::BodyTooLarge { limit: 8 });
    }

    #[test]
    fn header_limits_hold() {
        let mut giant = b"GET / HTTP/1.1\r\n".to_vec();
        for i in 0..(MAX_HEADERS + 1) {
            giant.extend_from_slice(format!("X-H{i}: v\r\n").as_bytes());
        }
        giant.extend_from_slice(b"\r\n");
        assert_eq!(parse_one(&giant).unwrap_err(), WireError::TooManyHeaders);

        let mut p = RequestParser::new(1024);
        p.feed(&vec![b'A'; MAX_HEAD_BYTES + 8]);
        assert_eq!(p.next().unwrap_err(), WireError::HeadersTooLarge);
    }

    #[test]
    fn chunked_framing_is_exact() {
        let head = write_chunked_head(200, "application/x-ndjson", true);
        let head = std::str::from_utf8(&head).unwrap();
        assert!(head.starts_with("HTTP/1.1 200 OK\r\n"), "{head}");
        assert!(head.contains("Transfer-Encoding: chunked\r\n"), "{head}");
        assert!(head.contains("Connection: keep-alive\r\n"), "{head}");
        assert!(!head.contains("Content-Length"), "chunked and Content-Length are exclusive");
        assert!(head.ends_with("\r\n\r\n"));

        assert_eq!(write_chunk(b"hello"), b"5\r\nhello\r\n");
        // Sizes are HEX per RFC 9112.
        let big = vec![b'x'; 26];
        assert_eq!(&write_chunk(&big)[..4], b"1a\r\n");
        assert_eq!(write_chunk(b""), b"", "empty data must not emit a terminator");
        assert_eq!(write_last_chunk(), b"0\r\n\r\n");
    }

    #[test]
    fn http10_defaults_to_close() {
        let req = parse_one(b"GET / HTTP/1.0\r\n\r\n").unwrap().unwrap();
        assert!(!req.keep_alive);
        let req = parse_one(b"GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n")
            .unwrap()
            .unwrap();
        assert!(req.keep_alive);
    }
}

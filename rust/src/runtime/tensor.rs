//! Host-side tensor values marshalled to/from PJRT literals.

use crate::linalg::Matrix;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    pub fn parse(s: &str) -> anyhow::Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => anyhow::bail!("unknown dtype '{other}'"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Dtype::F32 => "f32",
            Dtype::I32 => "i32",
        }
    }
}

#[derive(Clone, Debug, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// A host tensor: shape + typed buffer (row-major).
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

impl Tensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape, data: TensorData::F32(data) }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape, data: TensorData::I32(data) }
    }

    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor::f32(vec![], vec![v])
    }

    pub fn zeros_f32(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor::f32(shape, vec![0.0; n])
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn dtype(&self) -> Dtype {
        match self.data {
            TensorData::F32(_) => Dtype::F32,
            TensorData::I32(_) => Dtype::I32,
        }
    }

    pub fn as_f32(&self) -> &[f32] {
        match &self.data {
            TensorData::F32(v) => v,
            _ => panic!("tensor is not f32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> &mut [f32] {
        match &mut self.data {
            TensorData::F32(v) => v,
            _ => panic!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> &[i32] {
        match &self.data {
            TensorData::I32(v) => v,
            _ => panic!("tensor is not i32"),
        }
    }

    pub fn scalar(&self) -> f32 {
        assert_eq!(self.numel(), 1, "scalar() on non-scalar tensor");
        match &self.data {
            TensorData::F32(v) => v[0],
            TensorData::I32(v) => v[0] as f32,
        }
    }

    /// Matrix (f64) view of a 2-D f32 tensor.
    pub fn to_matrix(&self) -> Matrix {
        assert_eq!(self.shape.len(), 2, "to_matrix needs rank-2, got {:?}", self.shape);
        Matrix::from_f32(self.shape[0], self.shape[1], self.as_f32())
    }

    pub fn from_matrix(m: &Matrix) -> Tensor {
        Tensor::f32(vec![m.rows, m.cols], m.to_f32())
    }

    /// Convert to a PJRT literal.
    pub fn to_literal(&self) -> anyhow::Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        let lit = match &self.data {
            TensorData::F32(v) => xla::Literal::vec1(v.as_slice()),
            TensorData::I32(v) => xla::Literal::vec1(v.as_slice()),
        };
        Ok(lit.reshape(&dims)?)
    }

    /// Read a literal back into a host tensor with a known spec shape/dtype.
    pub fn from_literal(
        lit: &xla::Literal,
        shape: &[usize],
        dtype: Dtype,
    ) -> anyhow::Result<Tensor> {
        let t = match dtype {
            Dtype::F32 => Tensor::f32(shape.to_vec(), lit.to_vec::<f32>()?),
            Dtype::I32 => Tensor::i32(shape.to_vec(), lit.to_vec::<i32>()?),
        };
        Ok(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_accessors() {
        let t = Tensor::f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.numel(), 6);
        assert_eq!(t.dtype(), Dtype::F32);
        let m = t.to_matrix();
        assert_eq!(m.at(1, 2), 6.0);
        let back = Tensor::from_matrix(&m);
        assert_eq!(back, t);
    }

    #[test]
    fn scalars() {
        let t = Tensor::scalar_f32(3.5);
        assert_eq!(t.shape, Vec::<usize>::new());
        assert_eq!(t.scalar(), 3.5);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn shape_mismatch_panics() {
        Tensor::f32(vec![2, 2], vec![1.0]);
    }

    #[test]
    fn dtype_parse() {
        assert_eq!(Dtype::parse("f32").unwrap(), Dtype::F32);
        assert_eq!(Dtype::parse("i32").unwrap(), Dtype::I32);
        assert!(Dtype::parse("f64").is_err());
    }
}

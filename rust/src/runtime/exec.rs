//! PJRT execution: load HLO-text artifacts, compile once per entry point,
//! and run them from the Rust hot path with typed host tensors.
//!
//! This is the only module that touches the `xla` crate. Pattern follows
//! /opt/xla-example/load_hlo: `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `client.compile` → `execute`, with
//! `return_tuple=True` artifacts unwrapped via `to_tuple()`.

use std::collections::HashMap;
use std::path::Path;

use crate::model::manifest::{EntrySpec, Manifest};
use crate::runtime::tensor::Tensor;

/// A compiled entry point bound to its manifest spec.
pub struct CompiledEntry {
    pub spec: EntrySpec,
    exe: xla::PjRtLoadedExecutable,
}

impl CompiledEntry {
    /// Execute with inputs in manifest order. Validates shapes/dtypes
    /// against the spec before dispatch; returns outputs in manifest order.
    pub fn run(&self, inputs: &[Tensor]) -> anyhow::Result<Vec<Tensor>> {
        anyhow::ensure!(
            inputs.len() == self.spec.inputs.len(),
            "entry expects {} inputs, got {}",
            self.spec.inputs.len(),
            inputs.len()
        );
        let mut literals = Vec::with_capacity(inputs.len());
        for (t, s) in inputs.iter().zip(&self.spec.inputs) {
            anyhow::ensure!(
                t.shape == s.shape && t.dtype() == s.dtype,
                "input '{}' expects {:?} {:?}, got {:?} {:?}",
                s.name,
                s.shape,
                s.dtype,
                t.shape,
                t.dtype()
            );
            literals.push(t.to_literal()?);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        anyhow::ensure!(
            parts.len() == self.spec.outputs.len(),
            "entry returned {} outputs, manifest says {}",
            parts.len(),
            self.spec.outputs.len()
        );
        parts
            .iter()
            .zip(&self.spec.outputs)
            .map(|(lit, s)| Tensor::from_literal(lit, &s.shape, s.dtype))
            .collect()
    }
}

/// The runtime: one PJRT CPU client + lazily compiled entry points for one
/// artifact directory (one model config).
pub struct Runtime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: HashMap<String, CompiledEntry>,
}

impl Runtime {
    /// Load the manifest under `dir` and create the PJRT CPU client.
    pub fn load(dir: &Path) -> anyhow::Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        crate::info!(
            "runtime: PJRT platform={} devices={} artifacts={}",
            client.platform_name(),
            client.device_count(),
            dir.display()
        );
        Ok(Runtime { manifest, client, cache: HashMap::new() })
    }

    /// Compile (or fetch cached) an entry point.
    pub fn entry(&mut self, name: &str) -> anyhow::Result<&CompiledEntry> {
        if !self.cache.contains_key(name) {
            let spec = self.manifest.entry(name)?.clone();
            let path = self.manifest.hlo_path(name)?;
            let t = std::time::Instant::now();
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            crate::debug!("compiled entry '{name}' in {:.2}s", t.elapsed().as_secs_f64());
            self.cache.insert(name.to_string(), CompiledEntry { spec, exe });
        }
        Ok(&self.cache[name])
    }

    /// Convenience: compile-if-needed and run.
    pub fn run(&mut self, name: &str, inputs: &[Tensor]) -> anyhow::Result<Vec<Tensor>> {
        self.entry(name)?.run(inputs)
    }
}

//! PJRT runtime: the AOT bridge. Loads `artifacts/<config>/*.hlo.txt`
//! (produced once by `make artifacts`) and executes them from Rust —
//! Python is never on the request path.

pub mod exec;
pub mod tensor;

pub use exec::{CompiledEntry, Runtime};
pub use tensor::{Dtype, Tensor, TensorData};

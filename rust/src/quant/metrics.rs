//! Layer-error metrics: the calibrated objectives the paper optimizes and
//! plots (problem (2)/(3) and Fig. 2).

use crate::linalg::{matmul, Matrix};

/// `‖X·E‖_F² = Tr(Eᵀ H E)` computed from the Gram matrix `H = XᵀX`
/// without needing X itself (X has b·l rows; H is only m×m).
pub fn calibrated_error2(h: &Matrix, e: &Matrix) -> f64 {
    assert_eq!(h.rows, e.rows);
    // Tr(Eᵀ H E) = Σ_j e_jᵀ H e_j = Σ_ij (H E)_ij · E_ij
    let he = matmul(h, e);
    he.data.iter().zip(&e.data).map(|(a, b)| a * b).sum()
}

/// Relative calibrated error of a quantization: ‖X(Q−W)‖_F / ‖X·W‖_F.
pub fn relative_calibrated_error(h: &Matrix, w: &Matrix, q_deq: &Matrix) -> f64 {
    let num = calibrated_error2(h, &q_deq.sub(w)).max(0.0).sqrt();
    let den = calibrated_error2(h, w).max(1e-300).sqrt();
    num / den
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::norms::fro2;
    use crate::linalg::syrk_t;
    use crate::util::prng::Rng;

    #[test]
    fn matches_direct_computation() {
        let mut rng = Rng::new(70);
        let x = Matrix::randn(50, 12, 1.0, &mut rng);
        let e = Matrix::randn(12, 7, 1.0, &mut rng);
        let h = syrk_t(&x);
        let direct = fro2(&matmul(&x, &e));
        let via_h = calibrated_error2(&h, &e);
        assert!((direct - via_h).abs() < 1e-8 * direct);
    }

    #[test]
    fn zero_error_for_identical() {
        let mut rng = Rng::new(71);
        let x = Matrix::randn(30, 8, 1.0, &mut rng);
        let w = Matrix::randn(8, 4, 1.0, &mut rng);
        let h = syrk_t(&x);
        assert!(relative_calibrated_error(&h, &w, &w) < 1e-12);
    }
}

//! Asymmetric uniform integer (INT) quantizer with group-wise scaling —
//! the quantization grid from the paper's §2 (Background).
//!
//! Orientation convention (used across the whole repo): a layer computes
//! `Y = X · W` with `W ∈ ℝ^{m×n}` (`m` = input features = rows,
//! `n` = output channels = cols). Quantization groups run along the *input*
//! dimension: rows `[g·gs, (g+1)·gs)` of column `j` share one
//! `(scale, zero)` pair — the paper's "group size 64" default. Per-channel
//! quantization is `gs = m`.

use crate::linalg::Matrix;

/// Group-quantized weight tensor. `codes[i][j] ∈ {0, …, 2^bits − 1}`;
/// the dequantized value is `(codes[i][j] − zeros[g][j]) · scales[g][j]`
/// with `g = i / group_size`.
#[derive(Clone, Debug)]
pub struct QuantizedTensor {
    pub bits: u32,
    pub group_size: usize,
    pub rows: usize,
    pub cols: usize,
    /// m×n quantization codes (row-major, like `Matrix`).
    pub codes: Vec<u8>,
    /// num_groups×n scales.
    pub scales: Matrix,
    /// num_groups×n zero-points (stored as f64; integer-valued by
    /// construction, kept float for the dequant formula).
    pub zeros: Matrix,
}

impl QuantizedTensor {
    pub fn num_groups(&self) -> usize {
        self.scales.rows
    }

    #[inline]
    pub fn group_of_row(&self, i: usize) -> usize {
        i / self.group_size
    }

    /// Dequantize the full tensor.
    pub fn dequantize(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let g = self.group_of_row(i);
            for j in 0..self.cols {
                let c = self.codes[i * self.cols + j] as f64;
                out.set(i, j, (c - self.zeros.at(g, j)) * self.scales.at(g, j));
            }
        }
        out
    }

    /// Dequantize one row (hot in OPTQ's sequential loop).
    pub fn dequantize_row(&self, i: usize) -> Vec<f64> {
        let g = self.group_of_row(i);
        (0..self.cols)
            .map(|j| {
                let c = self.codes[i * self.cols + j] as f64;
                (c - self.zeros.at(g, j)) * self.scales.at(g, j)
            })
            .collect()
    }

    /// Storage cost in bits per weight (codes + per-group fp16 scale/zero
    /// amortized), the number quoted in memory footprints.
    pub fn bits_per_weight(&self) -> f64 {
        self.bits as f64 + 2.0 * 16.0 / self.group_size as f64
    }
}

/// Per-group quantization parameters for a row-block of a column.
#[derive(Clone, Copy, Debug)]
pub struct GroupParams {
    pub scale: f64,
    pub zero: f64,
}

/// Compute asymmetric (min/max) quantization parameters for a value set —
/// the paper's `δ = (max − min)/(2^b − 1)`, `z = −⌊min/δ⌉`.
pub fn find_params(values: &[f64], bits: u32) -> GroupParams {
    debug_assert!(!values.is_empty());
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for &v in values {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    // Grid must contain 0 so that e.g. padding rows stay exact.
    lo = lo.min(0.0);
    hi = hi.max(0.0);
    let levels = (1u32 << bits) - 1;
    let mut scale = (hi - lo) / levels as f64;
    if scale <= 0.0 || !scale.is_finite() {
        scale = 1.0; // degenerate all-zero group
    }
    let zero = -(lo / scale).round();
    GroupParams { scale, zero }
}

/// Quantize one value under `p`, returning (code, dequantized value).
#[inline]
pub fn quantize_value(v: f64, p: GroupParams, bits: u32) -> (u8, f64) {
    let qmax = ((1u32 << bits) - 1) as f64;
    let c = (v / p.scale + p.zero).round().clamp(0.0, qmax);
    (c as u8, (c - p.zero) * p.scale)
}

/// Straight RTN group quantization of a full matrix (the data-free
/// baseline; also the inner quantizer LoftQ alternates with).
pub fn quantize_rtn(w: &Matrix, bits: u32, group_size: usize) -> QuantizedTensor {
    let (m, n) = (w.rows, w.cols);
    let gs = group_size.min(m).max(1);
    let num_groups = m.div_ceil(gs);
    let mut codes = vec![0u8; m * n];
    let mut scales = Matrix::zeros(num_groups, n);
    let mut zeros = Matrix::zeros(num_groups, n);
    let mut col_buf = Vec::with_capacity(gs);
    for j in 0..n {
        for g in 0..num_groups {
            let r0 = g * gs;
            let r1 = ((g + 1) * gs).min(m);
            col_buf.clear();
            for i in r0..r1 {
                col_buf.push(w.at(i, j));
            }
            let p = find_params(&col_buf, bits);
            scales.set(g, j, p.scale);
            zeros.set(g, j, p.zero);
            for i in r0..r1 {
                let (c, _) = quantize_value(w.at(i, j), p, bits);
                codes[i * n + j] = c;
            }
        }
    }
    QuantizedTensor { bits, group_size: gs, rows: m, cols: n, codes, scales, zeros }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn params_cover_range() {
        let p = find_params(&[-1.0, 0.0, 3.0], 2);
        // 2-bit: 3 intervals over [-1, 3].
        assert!((p.scale - 4.0 / 3.0).abs() < 1e-12);
        let (c_lo, v_lo) = quantize_value(-1.0, p, 2);
        let (c_hi, v_hi) = quantize_value(3.0, p, 2);
        assert!(c_lo < c_hi);
        assert!((v_lo - -1.0).abs() < p.scale / 2.0 + 1e-12);
        assert!((v_hi - 3.0).abs() < p.scale / 2.0 + 1e-12);
    }

    #[test]
    fn rtn_error_bounded_by_half_step() {
        let mut rng = Rng::new(30);
        let w = Matrix::randn(64, 16, 1.0, &mut rng);
        for &bits in &[2u32, 3, 4, 8] {
            let q = quantize_rtn(&w, bits, 16);
            let deq = q.dequantize();
            for i in 0..w.rows {
                let g = q.group_of_row(i);
                for j in 0..w.cols {
                    let err = (w.at(i, j) - deq.at(i, j)).abs();
                    // zero-point rounding costs up to one extra half step
                    assert!(
                        err <= q.scales.at(g, j) + 1e-9,
                        "bits={bits} err={err} scale={}",
                        q.scales.at(g, j)
                    );
                }
            }
        }
    }

    #[test]
    fn idempotent_on_grid_values() {
        let mut rng = Rng::new(31);
        let w = Matrix::randn(32, 8, 1.0, &mut rng);
        let q1 = quantize_rtn(&w, 3, 8);
        let d1 = q1.dequantize();
        let q2 = quantize_rtn(&d1, 3, 8);
        let d2 = q2.dequantize();
        assert!(d1.max_diff(&d2) < 1e-9, "requantizing grid values must be exact");
    }

    #[test]
    fn higher_bits_lower_error() {
        let mut rng = Rng::new(32);
        let w = Matrix::randn(128, 8, 1.0, &mut rng);
        let errs: Vec<f64> = [2u32, 3, 4, 8]
            .iter()
            .map(|&b| {
                let deq = quantize_rtn(&w, b, 64).dequantize();
                crate::linalg::norms::fro(&w.sub(&deq))
            })
            .collect();
        assert!(errs[0] > errs[1] && errs[1] > errs[2] && errs[2] > errs[3], "{errs:?}");
    }

    #[test]
    fn smaller_groups_lower_error() {
        let mut rng = Rng::new(33);
        // Heavy-tailed weights make group granularity matter.
        let w = Matrix::from_fn(256, 4, |_, _| {
            let x = rng.gauss();
            x * x * x
        });
        let e16 = crate::linalg::norms::fro(&w.sub(&quantize_rtn(&w, 2, 16).dequantize()));
        let e256 = crate::linalg::norms::fro(&w.sub(&quantize_rtn(&w, 2, 256).dequantize()));
        assert!(e16 < e256, "e16={e16} e256={e256}");
    }

    #[test]
    fn group_independence() {
        // Changing weights in one group must not affect codes in another.
        let mut rng = Rng::new(34);
        let w1 = Matrix::randn(32, 4, 1.0, &mut rng);
        let mut w2 = w1.clone();
        for j in 0..4 {
            w2.set(0, j, 100.0); // perturb group 0 only
        }
        let q1 = quantize_rtn(&w1, 4, 8);
        let q2 = quantize_rtn(&w2, 4, 8);
        // Groups 1.. identical.
        for i in 8..32 {
            for j in 0..4 {
                assert_eq!(q1.codes[i * 4 + j], q2.codes[i * 4 + j]);
            }
        }
    }

    #[test]
    fn partial_last_group() {
        let mut rng = Rng::new(35);
        let w = Matrix::randn(10, 3, 1.0, &mut rng); // 10 rows, gs 4 → groups 4,4,2
        let q = quantize_rtn(&w, 4, 4);
        assert_eq!(q.num_groups(), 3);
        let deq = q.dequantize();
        assert!(crate::linalg::norms::fro(&w.sub(&deq)) < crate::linalg::norms::fro(&w));
    }

    #[test]
    fn zero_matrix_is_exact() {
        let w = Matrix::zeros(16, 4);
        let q = quantize_rtn(&w, 2, 8);
        assert!(q.dequantize().max_abs() < 1e-12);
    }

    #[test]
    fn bits_per_weight_accounting() {
        let w = Matrix::zeros(128, 4);
        let q = quantize_rtn(&w, 4, 64);
        assert!((q.bits_per_weight() - 4.5).abs() < 1e-12);
    }
}

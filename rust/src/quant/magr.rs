//! MagR — weight magnitude reduction preprocessing (Zhang et al., 2024a),
//! applied before OPTQ exactly as the paper's §4.1 prescribes.
//!
//! MagR replaces `W` by an (approximately) output-equivalent `Ŵ` with
//! smaller per-channel ℓ∞ magnitude, solving per output channel `j`:
//!
//! ```text
//!   min_{ŵ}  ‖X ŵ − X w_j‖²  +  α‖ŵ‖_∞
//! ```
//!
//! via **FISTA** (accelerated proximal gradient — plain ISTA moves at most
//! `η·α` per step and needs thousands of iterations on ill-conditioned H;
//! Nesterov momentum fixes that). The gradient step uses `H = XᵀX`; the
//! proximal operator of the ℓ∞ norm is `v − P_{αη·B₁}(v)` where `P_{t·B₁}`
//! is Euclidean projection onto the ℓ1-ball of radius `t`
//! (Moreau decomposition; projection by the Duchi et al. 2008 algorithm).
//!
//! Shrinking outliers tightens the per-group quantization grid, which is
//! where OPTQ loses most of its accuracy at 2-bit — MagR is what lets the
//! CLoQ pipeline stay calibrated in the ultra-low-bit regime.

use crate::linalg::Matrix;

#[derive(Clone, Debug)]
pub struct MagrConfig {
    /// ℓ∞ penalty weight, relative to mean |W| (the absolute α is
    /// `alpha_rel · mean|W|`). The MagR paper uses α ∈ [1e-4, 1e-2]·scale.
    pub alpha_rel: f64,
    pub iters: usize,
}

impl Default for MagrConfig {
    fn default() -> Self {
        Self { alpha_rel: 1e-3, iters: 60 }
    }
}

/// Euclidean projection of `v` onto the ℓ1-ball of radius `t`
/// (Duchi et al., "Efficient projections onto the ℓ1-ball").
pub fn project_l1_ball(v: &[f64], t: f64) -> Vec<f64> {
    let l1: f64 = v.iter().map(|x| x.abs()).sum();
    if l1 <= t || t <= 0.0 {
        return if t <= 0.0 { vec![0.0; v.len()] } else { v.to_vec() };
    }
    let mut mu: Vec<f64> = v.iter().map(|x| x.abs()).collect();
    mu.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let mut cumsum = 0.0;
    let mut theta = 0.0;
    for (k, &m) in mu.iter().enumerate() {
        cumsum += m;
        let th = (cumsum - t) / (k + 1) as f64;
        if m - th > 0.0 {
            theta = th;
        } else {
            break;
        }
    }
    v.iter()
        .map(|&x| x.signum() * (x.abs() - theta).max(0.0))
        .collect()
}

/// Proximal operator of `t·‖·‖_∞` via Moreau decomposition.
pub fn prox_linf(v: &[f64], t: f64) -> Vec<f64> {
    let p = project_l1_ball(v, t);
    v.iter().zip(&p).map(|(x, y)| x - y).collect()
}

/// Apply MagR to `w` (m×n) under Gram matrix `h` (m×m). Returns the
/// preprocessed Ŵ (same shape) whose columns have reduced ℓ∞ magnitude
/// while `‖X(Ŵ − W)‖_F` stays small.
pub fn magr(w: &Matrix, h: &Matrix, cfg: &MagrConfig) -> Matrix {
    let (m, n) = (w.rows, w.cols);
    assert_eq!(h.rows, m);
    // Step size 1/λ_max(H) (power iteration on symmetric H).
    let lmax = crate::linalg::norms::spectral(h).max(1e-12);
    let eta = 1.0 / lmax;
    let mean_abs = w.data.iter().map(|x| x.abs()).sum::<f64>() / (m * n) as f64;
    let alpha = cfg.alpha_rel * mean_abs * lmax; // scale-invariant penalty

    // FISTA in matrix form: all n columns advance together, so the gradient
    // step is ONE blocked GEMM `H·(Y − W)` per iteration instead of n
    // separate matvecs (≈3.5x faster end-to-end — EXPERIMENTS.md §Perf).
    // The ℓ∞ prox remains per-column (it is separable across columns).
    let mut v = w.clone();
    let mut v_prev = w.clone();
    let mut t_mom = 1.0f64;
    let mut col_buf = vec![0.0f64; m];
    for _ in 0..cfg.iters {
        let t_next = 0.5 * (1.0 + (1.0 + 4.0 * t_mom * t_mom).sqrt());
        let beta = (t_mom - 1.0) / t_next;
        // Y = V + β (V − V_prev);  grad = H (Y − W);  S = Y − η grad.
        let y = Matrix::from_fn(m, n, |i, j| {
            let vv = v.at(i, j);
            vv + beta * (vv - v_prev.at(i, j))
        });
        let grad = crate::linalg::matmul(h, &y.sub(w));
        let stepped = Matrix::from_fn(m, n, |i, j| y.at(i, j) - eta * grad.at(i, j));
        v_prev = std::mem::replace(
            &mut v,
            {
                let mut next = Matrix::zeros(m, n);
                for j in 0..n {
                    for i in 0..m {
                        col_buf[i] = stepped.at(i, j);
                    }
                    let p = prox_linf(&col_buf, eta * alpha);
                    next.set_col(j, &p);
                }
                next
            },
        );
        t_mom = t_next;
        // Early exit on stagnation (relative Frobenius change < 1e-5).
        let num: f64 = v
            .data
            .iter()
            .zip(&v_prev.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum();
        let den: f64 = v.data.iter().map(|x| x * x).sum();
        if num < 1e-10 * den.max(1e-300) {
            break;
        }
    }
    v
}

/// Max per-column ℓ∞ norm — the quantity MagR shrinks.
pub fn max_col_inf(w: &Matrix) -> f64 {
    (0..w.cols)
        .map(|j| w.col(j).iter().fold(0.0f64, |m, x| m.max(x.abs())))
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul, syrk_t};
    use crate::linalg::norms::fro;
    use crate::util::prng::Rng;

    #[test]
    fn l1_projection_properties() {
        let v = vec![3.0, -1.0, 0.5, 0.0];
        for &t in &[0.5, 1.0, 2.0, 10.0] {
            let p = project_l1_ball(&v, t);
            let l1: f64 = p.iter().map(|x| x.abs()).sum();
            assert!(l1 <= t + 1e-9, "t={t} l1={l1}");
            // Signs preserved, magnitudes shrunk.
            for (x, y) in v.iter().zip(&p) {
                assert!(y.abs() <= x.abs() + 1e-12);
                assert!(x * y >= 0.0);
            }
        }
        // Large radius: identity.
        let p = project_l1_ball(&v, 100.0);
        assert_eq!(p, v);
    }

    #[test]
    fn prox_linf_shrinks_max_only() {
        // prox of ℓ∞ clips the largest entries toward the rest.
        let v = vec![10.0, 1.0, -1.0];
        let p = prox_linf(&v, 3.0);
        assert!(p[0] < 10.0);
        assert!((p[1] - 1.0).abs() < 1e-9);
        assert!((p[2] + 1.0).abs() < 1e-9);
        let inf_before = 10.0f64;
        let inf_after = p.iter().fold(0.0f64, |m, x| m.max(x.abs()));
        assert!(inf_after < inf_before);
    }

    /// Activations with a fast-decaying spectrum (rank ~k effective), the
    /// regime where MagR has freedom: outliers can move along the near-null
    /// space of X without changing X·W.
    fn correlated_x(samples: usize, m: usize, k: usize, rng: &mut Rng) -> Matrix {
        let base = Matrix::randn(samples, k, 1.0, rng);
        let mix = Matrix::randn(k, m, 1.0, rng);
        matmul(&base, &mix)
    }

    #[test]
    fn magr_reduces_outliers_keeps_output() {
        let mut rng = Rng::new(60);
        let m = 32;
        let x = correlated_x(200, m, 8, &mut rng);
        let h = syrk_t(&x);
        // Weights with planted outliers (the regime MagR targets).
        let mut w = Matrix::randn(m, 8, 0.1, &mut rng);
        for k in 0..6 {
            let i = rng.below(m);
            let j = rng.below(8);
            w.set(i, j, if k % 2 == 0 { 3.0 } else { -3.0 });
        }
        let w2 = magr(&w, &h, &MagrConfig { alpha_rel: 0.05, iters: 100 });
        // (1) outlier magnitude reduced
        assert!(
            max_col_inf(&w2) < max_col_inf(&w) * 0.7,
            "inf before={} after={}",
            max_col_inf(&w),
            max_col_inf(&w2)
        );
        // (2) calibrated output preserved (relative error small)
        let num = fro(&matmul(&x, &w2.sub(&w)));
        let den = fro(&matmul(&x, &w));
        assert!(num / den < 0.05, "rel output drift {}", num / den);
    }

    #[test]
    fn magr_improves_low_bit_quantization() {
        // End-to-end motivation: RTN-2bit error after MagR ≤ before, on
        // outlier-heavy weights (deterministic seed where the effect is
        // clear, as in the MagR paper's Table 1 setting).
        use crate::quant::grid::quantize_rtn;
        use crate::quant::metrics::calibrated_error2;
        let mut rng = Rng::new(61);
        let m = 64;
        let x = correlated_x(256, m, 16, &mut rng);
        let h = syrk_t(&x);
        let mut w = Matrix::randn(m, 16, 0.1, &mut rng);
        for _ in 0..20 {
            let i = rng.below(m);
            let j = rng.below(16);
            w.set(i, j, rng.normal(0.0, 2.0));
        }
        let w_pre = magr(&w, &h, &MagrConfig::default());
        let e_plain = calibrated_error2(&h, &w.sub(&quantize_rtn(&w, 2, 64).dequantize()));
        // Note: error of the *pipeline* is vs the ORIGINAL W.
        let q_pre = quantize_rtn(&w_pre, 2, 64);
        let e_magr = calibrated_error2(&h, &w.sub(&q_pre.dequantize()));
        assert!(e_magr < e_plain, "magr {e_magr} vs plain {e_plain}");
    }

    #[test]
    fn zero_alpha_is_identityish() {
        let mut rng = Rng::new(62);
        let x = Matrix::randn(64, 16, 1.0, &mut rng);
        let h = syrk_t(&x);
        let w = Matrix::randn(16, 4, 1.0, &mut rng);
        let w2 = magr(&w, &h, &MagrConfig { alpha_rel: 0.0, iters: 10 });
        assert!(w.max_diff(&w2) < 1e-9);
    }
}

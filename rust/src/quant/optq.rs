//! OPTQ / GPTQ — calibrated post-training quantization
//! (Frantar et al., 2022), the quantization step of CLoQ (paper §3.1.1).
//!
//! Solves `min_Q ‖X(Q − W)‖_F²` approximately by quantizing the weight
//! matrix one *input dimension* (row of `W`, in our `Y = X·W` orientation)
//! at a time, spreading each row's rounding error over the not-yet-quantized
//! rows using the inverse Hessian `H⁻¹ = (XᵀX + λI)⁻¹`:
//!
//! ```text
//!   U = chol(H⁻¹)ᵀ            (upper triangular, H⁻¹ = UᵀU)
//!   for i = 0..m:
//!       q_i   = quant(w_i)                    (group params refreshed at
//!                                              group boundaries)
//!       err   = (w_i − q_i) / U[i,i]
//!       w_k  -= U[i,k] · err    for k > i
//! ```
//!
//! # The blocked (lazy-batch) engine
//!
//! The recursion above touches EVERY remaining row after quantizing each
//! row — m passes over an ever-shrinking trailing submatrix, which goes
//! memory-bound as soon as `W` falls out of L2 (512×512 f64 is already
//! 2 MiB). [`optq`] therefore runs GPTQ's lazy-batch blocking: rows are
//! quantized in blocks of [`OptqConfig::block_size`]; inside a block the
//! error is spread immediately (the block is cache-hot), while the update
//! to the rows *beyond* the block is accumulated in an error panel `E` and
//! applied once per block as a single panel product
//! `W_tail -= U_panelᵀ·E` ([`sub_matmul_tn_tail`]) — the trailing matrix is
//! streamed once per block instead of once per row.
//!
//! **Parity contract:** the blocked engine is BIT-IDENTICAL to the
//! row-by-row reference ([`optq_unblocked`], retained as the oracle), for
//! every bit-width / group size / block size / act-order setting. Two
//! properties make this exact rather than approximate:
//!
//! * the deferred panel product accumulates each trailing element's
//!   updates in ascending row order — the same per-element floating-point
//!   op sequence the reference applies one row at a time;
//! * lazy group-parameter fits that need a trailing member's value replay
//!   the block's pending updates for that member on a copy, in the same
//!   order, before fitting (`fit_group_blocked`).
//!
//! `rust/tests/parity_blocked.rs` locks this down across bits ∈ {2,3,4},
//! group sizes, non-divisible block edges and act-order; the speedup is
//! measured by `cargo bench --bench bench_optq` (≥2× on a 512×512 layer —
//! see EXPERIMENTS.md §Perf, which also covers the `chol_inv_upper` root
//! that replaced the seed's `inv_spd`+`cholesky` setup in BOTH paths).

use super::grid::{find_params, quantize_value, GroupParams, QuantizedTensor};
use crate::linalg::blas::axpy_sub;
use crate::linalg::chol::chol_inv_upper;
use crate::linalg::{sub_matmul_tn_tail, Matrix};

/// OPTQ configuration.
#[derive(Clone, Debug)]
pub struct OptqConfig {
    pub bits: u32,
    pub group_size: usize,
    /// Diagonal damping as a fraction of mean(diag(H)) — the paper's
    /// `λ = 0.01·Tr(H)/m`.
    pub damp_percent: f64,
    /// Process rows in descending diag(H) order (GPTQ's `act_order` /
    /// "activation order" heuristic). Ablated in `bench_optq`.
    pub act_order: bool,
    /// Lazy-batch block size: rows quantized per block before the
    /// accumulated error panel is applied to the trailing rows as one
    /// product. `<= 1` selects the row-by-row reference path.
    pub block_size: usize,
}

impl Default for OptqConfig {
    fn default() -> Self {
        Self { bits: 4, group_size: 64, damp_percent: 0.01, act_order: false, block_size: 32 }
    }
}

/// Shared state of both engines after setup: the row permutation, the
/// inverse-Hessian root, and the permuted working copy of `W`.
struct Prep {
    /// Permuted position → original row index.
    order: Vec<usize>,
    /// Original row index → permuted position.
    pos_of: Vec<usize>,
    /// Upper-triangular `U` with `H_p⁻¹ = UᵀU` (damped, permuted H).
    u: Matrix,
    /// Working copy of `W` in permuted row order.
    wp: Matrix,
    /// Effective group size (clamped to `[1, m]`).
    gs: usize,
}

fn prepare(w: &Matrix, h: &Matrix, cfg: &OptqConfig) -> Prep {
    let m = w.rows;
    assert_eq!(h.rows, m);
    assert_eq!(h.cols, m);
    let gs = cfg.group_size.min(m).max(1);

    // Row processing order (act_order: largest diag(H) first — quantize the
    // most activation-salient inputs before error accumulates).
    let mut order: Vec<usize> = (0..m).collect();
    if cfg.act_order {
        order.sort_by(|&a, &b| h.at(b, b).partial_cmp(&h.at(a, a)).unwrap());
    }
    let mut pos_of = vec![0usize; m];
    for (p, &orig) in order.iter().enumerate() {
        pos_of[orig] = p;
    }

    // Permuted, damped Hessian.
    let lambda = cfg.damp_percent * h.trace() / m as f64;
    let mut hp = Matrix::from_fn(m, m, |i, j| h.at(order[i], order[j]));
    hp.add_diag(lambda.max(1e-12));

    // U with H⁻¹ = UᵀU via the flip-Cholesky route (no explicit inverse),
    // with escalating damping if H is badly conditioned.
    let mut extra = 0.0;
    let u = loop {
        let mut hd = hp.clone();
        if extra > 0.0 {
            hd.add_diag(extra);
        }
        match chol_inv_upper(&hd) {
            Ok(u) => break u,
            Err(_) => {
                extra = if extra == 0.0 { lambda.max(1e-9) } else { extra * 10.0 };
                assert!(extra.is_finite() && extra < 1e18, "optq: H damping diverged");
            }
        }
    };

    let wp = Matrix::from_fn(m, w.cols, |i, j| w.at(order[i], j));
    Prep { order, pos_of, u, wp, gs }
}

/// Per-layer output bookkeeping shared by both engines. Group params follow
/// the *original* row index so the output layout matches `QuantizedTensor`'s
/// group-per-consecutive-rows scheme; with act_order on, rows of one group
/// may be visited out of order, so params are computed lazily per group from
/// the current error-compensated state the first time any member is visited.
struct Out {
    scales: Matrix,
    zeros: Matrix,
    group_ready: Vec<bool>,
    codes: Vec<u8>,
}

impl Out {
    fn new(m: usize, n: usize, gs: usize) -> Out {
        let num_groups = m.div_ceil(gs);
        Out {
            scales: Matrix::zeros(num_groups, n),
            zeros: Matrix::zeros(num_groups, n),
            group_ready: vec![false; num_groups],
            codes: vec![0u8; m * n],
        }
    }
}

/// Quantize `w` (m×n) against Gram matrix `h` (m×m, *undamped*; we damp a
/// copy internally) with the blocked lazy-batch engine. Returns the
/// quantized tensor; `q.dequantize()` lies on the quantization grid.
/// Bit-identical to [`optq_unblocked`] (see the module docs).
pub fn optq(w: &Matrix, h: &Matrix, cfg: &OptqConfig) -> QuantizedTensor {
    if cfg.block_size <= 1 {
        return optq_unblocked(w, h, cfg);
    }
    let (m, n) = (w.rows, w.cols);
    let mut p = prepare(w, h, cfg);
    let gs = p.gs;
    let mut out = Out::new(m, n, gs);

    let bs = cfg.block_size.min(m.max(1));
    let mut errs = Matrix::zeros(bs, n);
    let mut b0 = 0usize;
    while b0 < m {
        let b1 = (b0 + bs).min(m);
        for i in b0..b1 {
            let orig_row = p.order[i];
            let g = orig_row / gs;
            if !out.group_ready[g] {
                fit_group_blocked(&p, &errs, &mut out, g, b0, b1, i, cfg.bits);
            }
            let d = p.u.at(i, i);
            for j in 0..n {
                let gp = GroupParams { scale: out.scales.at(g, j), zero: out.zeros.at(g, j) };
                let wv = p.wp.at(i, j);
                let (c, dq) = quantize_value(wv, gp, cfg.bits);
                out.codes[orig_row * n + j] = c;
                errs.set(i - b0, j, (wv - dq) / d);
            }
            // Spread the error over the rest of the block immediately (the
            // block is cache-hot); rows beyond the block wait for the panel
            // product below.
            for k in i + 1..b1 {
                let uik = p.u.at(i, k);
                if uik == 0.0 {
                    continue;
                }
                axpy_sub(p.wp.row_mut(k), uik, errs.row(i - b0));
            }
        }
        // Deferred update: wp[b1.., :] -= U[b0..b1, b1..]ᵀ · E, one pass
        // over the trailing rows per block.
        sub_matmul_tn_tail(&mut p.wp, b1, &p.u, b0, b1 - b0, &errs);
        b0 = b1;
    }

    QuantizedTensor {
        bits: cfg.bits,
        group_size: gs,
        rows: m,
        cols: n,
        codes: out.codes,
        scales: out.scales,
        zeros: out.zeros,
    }
}

/// Lazy group-parameter fit for the blocked engine. Members at permuted
/// positions `>= b1` have not yet received this block's deferred updates,
/// so replay the pending updates from rows `b0..i` on a copy of their
/// value — in the same ascending order the reference path applied them —
/// before fitting. Members inside the block (or in flushed blocks) are
/// already exact.
#[allow(clippy::too_many_arguments)]
fn fit_group_blocked(
    p: &Prep,
    errs: &Matrix,
    out: &mut Out,
    g: usize,
    b0: usize,
    b1: usize,
    i: usize,
    bits: u32,
) {
    let m = p.wp.rows;
    let n = p.wp.cols;
    let r0 = g * p.gs;
    let r1 = ((g + 1) * p.gs).min(m);
    let mut vals = Vec::with_capacity(r1 - r0);
    for j in 0..n {
        vals.clear();
        for orig in r0..r1 {
            let pos = p.pos_of[orig];
            let mut v = p.wp.at(pos, j);
            if pos >= b1 {
                for t in b0..i {
                    let utp = p.u.at(t, pos);
                    if utp != 0.0 {
                        v -= utp * errs.at(t - b0, j);
                    }
                }
            }
            vals.push(v);
        }
        let gp = find_params(&vals, bits);
        out.scales.set(g, j, gp.scale);
        out.zeros.set(g, j, gp.zero);
    }
    out.group_ready[g] = true;
}

/// The row-by-row reference recursion (the seed's inner loop, retained
/// verbatim as the parity oracle): after quantizing each row, its error is
/// spread over ALL remaining rows immediately. O(m) passes over the
/// trailing submatrix — use [`optq`] everywhere except as a comparison
/// baseline.
pub fn optq_unblocked(w: &Matrix, h: &Matrix, cfg: &OptqConfig) -> QuantizedTensor {
    let (m, n) = (w.rows, w.cols);
    let mut p = prepare(w, h, cfg);
    let gs = p.gs;
    let mut out = Out::new(m, n, gs);

    let mut err = vec![0.0f64; n];
    for i in 0..m {
        let orig_row = p.order[i];
        let g = orig_row / gs;
        if !out.group_ready[g] {
            // Fit params from the current (error-compensated) values of all
            // group members, read from wp at their permuted positions.
            let r0 = g * gs;
            let r1 = ((g + 1) * gs).min(m);
            for j in 0..n {
                let vals: Vec<f64> = (r0..r1).map(|orig| p.wp.at(p.pos_of[orig], j)).collect();
                let gp = find_params(&vals, cfg.bits);
                out.scales.set(g, j, gp.scale);
                out.zeros.set(g, j, gp.zero);
            }
            out.group_ready[g] = true;
        }

        let d = p.u.at(i, i);
        for j in 0..n {
            let gp = GroupParams { scale: out.scales.at(g, j), zero: out.zeros.at(g, j) };
            let wv = p.wp.at(i, j);
            let (c, dq) = quantize_value(wv, gp, cfg.bits);
            out.codes[orig_row * n + j] = c;
            err[j] = (wv - dq) / d;
        }
        // Spread the error over the remaining rows: w_k -= U[i,k] · err.
        for k in i + 1..m {
            let uik = p.u.at(i, k);
            if uik == 0.0 {
                continue;
            }
            axpy_sub(p.wp.row_mut(k), uik, &err);
        }
    }

    QuantizedTensor {
        bits: cfg.bits,
        group_size: gs,
        rows: m,
        cols: n,
        codes: out.codes,
        scales: out.scales,
        zeros: out.zeros,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::syrk_t;
    use crate::quant::grid::quantize_rtn;
    use crate::quant::metrics::calibrated_error2;
    use crate::util::prng::Rng;

    fn setup(m: usize, n: usize, samples: usize, seed: u64) -> (Matrix, Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        // Correlated activations (realistic: features share variance).
        let base = Matrix::randn(samples, m, 1.0, &mut rng);
        let mix = Matrix::randn(m, m, 0.3, &mut rng);
        let x = crate::linalg::matmul(&base, &mix.add(&Matrix::eye(m)));
        let w = Matrix::randn(m, n, 0.5, &mut rng);
        let h = syrk_t(&x);
        (x, w, h)
    }

    #[test]
    fn output_on_grid() {
        let (_, w, h) = setup(32, 16, 128, 50);
        let cfg = OptqConfig { bits: 3, group_size: 16, ..Default::default() };
        let q = optq(&w, &h, &cfg);
        // Re-quantizing the dequantized output with the same params is exact.
        let deq = q.dequantize();
        for i in 0..w.rows {
            let g = q.group_of_row(i);
            for j in 0..w.cols {
                let p = GroupParams { scale: q.scales.at(g, j), zero: q.zeros.at(g, j) };
                let (_, v) = quantize_value(deq.at(i, j), p, 3);
                assert!((v - deq.at(i, j)).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn blocked_equals_reference_smoke() {
        // The full sweep lives in tests/parity_blocked.rs; this is the
        // in-module smoke check.
        let (_, w, h) = setup(37, 11, 120, 58);
        for bs in [2usize, 8, 37, 100] {
            let cfg = OptqConfig { bits: 3, group_size: 10, block_size: bs, ..Default::default() };
            let a = optq(&w, &h, &cfg);
            let b = optq_unblocked(&w, &h, &cfg);
            assert_eq!(a.codes, b.codes, "bs={bs}");
            assert_eq!(a.scales.data, b.scales.data, "bs={bs}");
            assert_eq!(a.zeros.data, b.zeros.data, "bs={bs}");
        }
    }

    #[test]
    fn beats_rtn_on_calibrated_error() {
        for seed in [51u64, 52, 53] {
            let (_, w, h) = setup(48, 24, 256, seed);
            for &bits in &[2u32, 3, 4] {
                let cfg = OptqConfig { bits, group_size: 16, ..Default::default() };
                let q_optq = optq(&w, &h, &cfg);
                let q_rtn = quantize_rtn(&w, bits, 16);
                let e_optq = calibrated_error2(&h, &w.sub(&q_optq.dequantize()));
                let e_rtn = calibrated_error2(&h, &w.sub(&q_rtn.dequantize()));
                assert!(
                    e_optq <= e_rtn * 1.001,
                    "seed={seed} bits={bits}: optq {e_optq} vs rtn {e_rtn}"
                );
            }
        }
    }

    #[test]
    fn higher_bits_monotone() {
        let (_, w, h) = setup(32, 8, 128, 54);
        let errs: Vec<f64> = [2u32, 3, 4]
            .iter()
            .map(|&bits| {
                let cfg = OptqConfig { bits, group_size: 32, ..Default::default() };
                let q = optq(&w, &h, &cfg);
                calibrated_error2(&h, &w.sub(&q.dequantize()))
            })
            .collect();
        assert!(errs[0] > errs[1] && errs[1] > errs[2], "{errs:?}");
    }

    #[test]
    fn act_order_runs_and_is_competitive() {
        let (_, w, h) = setup(40, 12, 160, 55);
        let base = OptqConfig { bits: 2, group_size: 40, ..Default::default() };
        let ao = OptqConfig { act_order: true, ..base.clone() };
        let e_base = calibrated_error2(&h, &w.sub(&optq(&w, &h, &base).dequantize()));
        let e_ao = calibrated_error2(&h, &w.sub(&optq(&w, &h, &ao).dequantize()));
        // act_order usually helps at 2-bit per-channel; at minimum it must
        // stay in the same ballpark (not a correctness property, a sanity
        // band — 2× tolerance).
        assert!(e_ao < e_base * 2.0, "e_ao={e_ao} e_base={e_base}");
    }

    #[test]
    fn identity_hessian_reduces_to_rtn() {
        // With H = I there is no cross-row information: OPTQ == RTN when the
        // whole matrix is a single group and rows are processed in order.
        let mut rng = Rng::new(56);
        let w = Matrix::randn(24, 6, 1.0, &mut rng);
        let h = Matrix::eye(24);
        let cfg = OptqConfig { bits: 4, group_size: 24, damp_percent: 0.0, ..Default::default() };
        let q = optq(&w, &h, &cfg);
        let r = quantize_rtn(&w, 4, 24);
        // Identical codes (error feedback is still applied but U is diagonal
        // ⇒ off-diagonal terms vanish ⇒ no compensation happens).
        assert_eq!(q.codes, r.codes);
    }

    #[test]
    fn rank_deficient_hessian_handled() {
        // Fewer samples than features: H singular; damping must rescue it.
        let mut rng = Rng::new(57);
        let x = Matrix::randn(8, 32, 1.0, &mut rng);
        let w = Matrix::randn(32, 8, 1.0, &mut rng);
        let h = syrk_t(&x);
        let cfg = OptqConfig { bits: 4, group_size: 32, ..Default::default() };
        let q = optq(&w, &h, &cfg);
        assert!(q.dequantize().max_abs().is_finite());
    }
}

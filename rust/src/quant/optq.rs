//! OPTQ / GPTQ — calibrated post-training quantization
//! (Frantar et al., 2022), the quantization step of CLoQ (paper §3.1.1).
//!
//! Solves `min_Q ‖X(Q − W)‖_F²` approximately by quantizing the weight
//! matrix one *input dimension* (row of `W`, in our `Y = X·W` orientation)
//! at a time, spreading each row's rounding error over the not-yet-quantized
//! rows using the inverse Hessian `H⁻¹ = (XᵀX + λI)⁻¹`:
//!
//! ```text
//!   U = chol(H⁻¹)ᵀ            (upper triangular, H⁻¹ = UᵀU)
//!   for i = 0..m:
//!       q_i   = quant(w_i)                    (group params refreshed at
//!                                              group boundaries)
//!       err   = (w_i − q_i) / U[i,i]
//!       w_k  -= U[i,k] · err    for k > i
//! ```
//!
//! This is exactly the GPTQ recursion, expressed without the lazy-batch
//! blocking (layer sizes here are ≤ ~1k so the simple form is both clear
//! and fast — see EXPERIMENTS.md §Perf for measurements).

use super::grid::{find_params, quantize_value, GroupParams, QuantizedTensor};
use crate::linalg::chol::{cholesky, inv_spd};
use crate::linalg::Matrix;

/// OPTQ configuration.
#[derive(Clone, Debug)]
pub struct OptqConfig {
    pub bits: u32,
    pub group_size: usize,
    /// Diagonal damping as a fraction of mean(diag(H)) — the paper's
    /// `λ = 0.01·Tr(H)/m`.
    pub damp_percent: f64,
    /// Process rows in descending diag(H) order (GPTQ's `act_order` /
    /// "activation order" heuristic). Ablated in `bench_optq`.
    pub act_order: bool,
}

impl Default for OptqConfig {
    fn default() -> Self {
        Self { bits: 4, group_size: 64, damp_percent: 0.01, act_order: false }
    }
}

/// Quantize `w` (m×n) against Gram matrix `h` (m×m, *undamped*; we damp a
/// copy internally). Returns the quantized tensor; `q.dequantize()` lies on
/// the quantization grid.
pub fn optq(w: &Matrix, h: &Matrix, cfg: &OptqConfig) -> QuantizedTensor {
    let (m, n) = (w.rows, w.cols);
    assert_eq!(h.rows, m);
    assert_eq!(h.cols, m);
    let gs = cfg.group_size.min(m).max(1);

    // Row processing order (act_order: largest diag(H) first — quantize the
    // most activation-salient inputs before error accumulates).
    let mut order: Vec<usize> = (0..m).collect();
    if cfg.act_order {
        order.sort_by(|&a, &b| h.at(b, b).partial_cmp(&h.at(a, a)).unwrap());
    }

    // Permuted, damped Hessian.
    let lambda = cfg.damp_percent * h.trace() / m as f64;
    let mut hp = Matrix::from_fn(m, m, |i, j| h.at(order[i], order[j]));
    hp.add_diag(lambda.max(1e-12));

    // U = chol(H⁻¹)ᵀ with escalating damping if H is badly conditioned.
    let mut extra = 0.0;
    let u = loop {
        let mut hd = hp.clone();
        if extra > 0.0 {
            hd.add_diag(extra);
        }
        match inv_spd(&hd).and_then(|hinv| cholesky(&hinv)) {
            Ok(l) => break l.transpose(),
            Err(_) => {
                extra = if extra == 0.0 { lambda.max(1e-9) } else { extra * 10.0 };
                assert!(extra.is_finite() && extra < 1e18, "optq: H damping diverged");
            }
        }
    };

    // Working copy of W in permuted row order.
    let mut wp = Matrix::from_fn(m, n, |i, j| w.at(order[i], j));

    // Group bookkeeping follows the *original* row index so the output
    // layout matches `QuantizedTensor`'s group-per-consecutive-rows scheme.
    // With act_order on, rows of one group may be visited out of order, so
    // params are computed lazily per (group, col) from the current wp state
    // the first time any row of the group is quantized.
    let num_groups = m.div_ceil(gs);
    let mut scales = Matrix::zeros(num_groups, n);
    let mut zeros = Matrix::zeros(num_groups, n);
    let mut group_ready = vec![false; num_groups];
    let mut codes = vec![0u8; m * n];

    // Map original row → permuted position (to gather group members).
    let mut pos_of = vec![0usize; m];
    for (p, &orig) in order.iter().enumerate() {
        pos_of[orig] = p;
    }

    let mut err = vec![0.0f64; n];
    for i in 0..m {
        let orig_row = order[i];
        let g = orig_row / gs;
        if !group_ready[g] {
            // Fit params from the current (error-compensated) values of all
            // group members, read from wp at their permuted positions.
            let r0 = g * gs;
            let r1 = ((g + 1) * gs).min(m);
            for j in 0..n {
                let vals: Vec<f64> = (r0..r1).map(|orig| wp.at(pos_of[orig], j)).collect();
                let p = find_params(&vals, cfg.bits);
                scales.set(g, j, p.scale);
                zeros.set(g, j, p.zero);
            }
            group_ready[g] = true;
        }

        let d = u.at(i, i);
        for j in 0..n {
            let p = GroupParams { scale: scales.at(g, j), zero: zeros.at(g, j) };
            let wv = wp.at(i, j);
            let (c, dq) = quantize_value(wv, p, cfg.bits);
            codes[orig_row * n + j] = c;
            err[j] = (wv - dq) / d;
        }
        // Spread the error over the remaining rows: w_k -= U[i,k] · err.
        for k in i + 1..m {
            let uik = u.at(i, k);
            if uik == 0.0 {
                continue;
            }
            let row = wp.row_mut(k);
            for j in 0..n {
                row[j] -= uik * err[j];
            }
        }
    }

    QuantizedTensor { bits: cfg.bits, group_size: gs, rows: m, cols: n, codes, scales, zeros }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::syrk_t;
    use crate::quant::grid::quantize_rtn;
    use crate::quant::metrics::calibrated_error2;
    use crate::util::prng::Rng;

    fn setup(m: usize, n: usize, samples: usize, seed: u64) -> (Matrix, Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        // Correlated activations (realistic: features share variance).
        let base = Matrix::randn(samples, m, 1.0, &mut rng);
        let mix = Matrix::randn(m, m, 0.3, &mut rng);
        let x = crate::linalg::matmul(&base, &mix.add(&Matrix::eye(m)));
        let w = Matrix::randn(m, n, 0.5, &mut rng);
        let h = syrk_t(&x);
        (x, w, h)
    }

    #[test]
    fn output_on_grid() {
        let (_, w, h) = setup(32, 16, 128, 50);
        let cfg = OptqConfig { bits: 3, group_size: 16, ..Default::default() };
        let q = optq(&w, &h, &cfg);
        // Re-quantizing the dequantized output with the same params is exact.
        let deq = q.dequantize();
        for i in 0..w.rows {
            let g = q.group_of_row(i);
            for j in 0..w.cols {
                let p = GroupParams { scale: q.scales.at(g, j), zero: q.zeros.at(g, j) };
                let (_, v) = quantize_value(deq.at(i, j), p, 3);
                assert!((v - deq.at(i, j)).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn beats_rtn_on_calibrated_error() {
        for seed in [51u64, 52, 53] {
            let (_, w, h) = setup(48, 24, 256, seed);
            for &bits in &[2u32, 3, 4] {
                let cfg = OptqConfig { bits, group_size: 16, ..Default::default() };
                let q_optq = optq(&w, &h, &cfg);
                let q_rtn = quantize_rtn(&w, bits, 16);
                let e_optq = calibrated_error2(&h, &w.sub(&q_optq.dequantize()));
                let e_rtn = calibrated_error2(&h, &w.sub(&q_rtn.dequantize()));
                assert!(
                    e_optq <= e_rtn * 1.001,
                    "seed={seed} bits={bits}: optq {e_optq} vs rtn {e_rtn}"
                );
            }
        }
    }

    #[test]
    fn higher_bits_monotone() {
        let (_, w, h) = setup(32, 8, 128, 54);
        let errs: Vec<f64> = [2u32, 3, 4]
            .iter()
            .map(|&bits| {
                let cfg = OptqConfig { bits, group_size: 32, ..Default::default() };
                let q = optq(&w, &h, &cfg);
                calibrated_error2(&h, &w.sub(&q.dequantize()))
            })
            .collect();
        assert!(errs[0] > errs[1] && errs[1] > errs[2], "{errs:?}");
    }

    #[test]
    fn act_order_runs_and_is_competitive() {
        let (_, w, h) = setup(40, 12, 160, 55);
        let base = OptqConfig { bits: 2, group_size: 40, ..Default::default() };
        let ao = OptqConfig { act_order: true, ..base.clone() };
        let e_base = calibrated_error2(&h, &w.sub(&optq(&w, &h, &base).dequantize()));
        let e_ao = calibrated_error2(&h, &w.sub(&optq(&w, &h, &ao).dequantize()));
        // act_order usually helps at 2-bit per-channel; at minimum it must
        // stay in the same ballpark (not a correctness property, a sanity
        // band — 2× tolerance).
        assert!(e_ao < e_base * 2.0, "e_ao={e_ao} e_base={e_base}");
    }

    #[test]
    fn identity_hessian_reduces_to_rtn() {
        // With H = I there is no cross-row information: OPTQ == RTN when the
        // whole matrix is a single group and rows are processed in order.
        let mut rng = Rng::new(56);
        let w = Matrix::randn(24, 6, 1.0, &mut rng);
        let h = Matrix::eye(24);
        let cfg = OptqConfig { bits: 4, group_size: 24, damp_percent: 0.0, act_order: false };
        let q = optq(&w, &h, &cfg);
        let r = quantize_rtn(&w, 4, 24);
        // Identical codes (error feedback is still applied but U is diagonal
        // ⇒ off-diagonal terms vanish ⇒ no compensation happens).
        assert_eq!(q.codes, r.codes);
    }

    #[test]
    fn rank_deficient_hessian_handled() {
        // Fewer samples than features: H singular; damping must rescue it.
        let mut rng = Rng::new(57);
        let x = Matrix::randn(8, 32, 1.0, &mut rng);
        let w = Matrix::randn(32, 8, 1.0, &mut rng);
        let h = syrk_t(&x);
        let cfg = OptqConfig { bits: 4, group_size: 32, ..Default::default() };
        let q = optq(&w, &h, &cfg);
        assert!(q.dequantize().max_abs().is_finite());
    }
}

//! Quantization substrate: the INT grid (paper §2), RTN, NF-k (QLoRA's
//! format), OPTQ/GPTQ calibrated PTQ (paper §3.1.1), MagR preprocessing,
//! code bit-packing, and the calibrated error metrics.

pub mod grid;
pub mod magr;
pub mod metrics;
pub mod nf;
pub mod optq;
pub mod packing;

pub use grid::{quantize_rtn, QuantizedTensor};
pub use magr::{magr, MagrConfig};
pub use metrics::{calibrated_error2, relative_calibrated_error};
pub use nf::{quantize_nf, NfQuantized};
pub use optq::{optq, OptqConfig};

use crate::linalg::Matrix;

/// The exact quantization state an init method hands to the packed serving
/// path: either the asymmetric INT grid (RTN / OPTQ) or the NF-k codebook
/// (QLoRA). Both carry small-integer codes that bit-pack losslessly
/// (`packing::pack_codes`); `dequantize` is the dense reference the fused
/// serve kernel (`serve::packed`) is parity-tested against bit-for-bit.
#[derive(Clone, Debug)]
pub enum QuantState {
    Int(QuantizedTensor),
    Nf(NfQuantized),
}

impl QuantState {
    pub fn rows(&self) -> usize {
        match self {
            QuantState::Int(q) => q.rows,
            QuantState::Nf(q) => q.rows,
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            QuantState::Int(q) => q.cols,
            QuantState::Nf(q) => q.cols,
        }
    }

    pub fn bits(&self) -> u32 {
        match self {
            QuantState::Int(q) => q.bits,
            QuantState::Nf(q) => q.bits,
        }
    }

    /// Rows sharing one (scale, zero) / absmax entry (NF calls it a block).
    pub fn group_size(&self) -> usize {
        match self {
            QuantState::Int(q) => q.group_size,
            QuantState::Nf(q) => q.block_size,
        }
    }

    /// Dense dequantized values — the serve parity reference.
    pub fn dequantize(&self) -> Matrix {
        match self {
            QuantState::Int(q) => q.dequantize(),
            QuantState::Nf(q) => q.dequantize(),
        }
    }
}

//! Quantization substrate: the INT grid (paper §2), RTN, NF-k (QLoRA's
//! format), OPTQ/GPTQ calibrated PTQ (paper §3.1.1), MagR preprocessing,
//! code bit-packing, and the calibrated error metrics.

pub mod grid;
pub mod magr;
pub mod metrics;
pub mod nf;
pub mod optq;
pub mod packing;

pub use grid::{quantize_rtn, QuantizedTensor};
pub use magr::{magr, MagrConfig};
pub use metrics::{calibrated_error2, relative_calibrated_error};
pub use nf::{quantize_nf, NfQuantized};
pub use optq::{optq, OptqConfig};

//! NormalFloat (NF-k) codebook quantization — the QLoRA baseline's format
//! (Dettmers et al., 2023), generalized to 2/3/4 bits.
//!
//! NF4 uses the information-theoretically-motivated codebook of standard
//! normal quantiles, rescaled so the largest magnitude maps to ±1, with an
//! exact zero level. Blocks share an absmax scale. We hardcode the published
//! NF4 codebook (bit-exact with bitsandbytes) and generate NF2/NF3 from the
//! same quantile construction so QLoRA can be swept across bit-widths like
//! the paper's Table 3 does.

use crate::linalg::Matrix;

/// The published NF4 codebook (bitsandbytes `create_normal_map` output).
pub const NF4_LEVELS: [f64; 16] = [
    -1.0,
    -0.6961928009986877,
    -0.5250730514526367,
    -0.39491748809814453,
    -0.28444138169288635,
    -0.18477343022823334,
    -0.09105003625154495,
    0.0,
    0.07958029955625534,
    0.16093020141124725,
    0.24611230194568634,
    0.33791524171829224,
    0.44070982933044434,
    0.5626170039176941,
    0.7229568362236023,
    1.0,
];

/// Inverse standard-normal CDF (Acklam's rational approximation, |err| < 1.15e-9).
pub fn probit(p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0, "probit domain");
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    let p_low = 0.02425;
    if p < p_low {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - p_low {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        -probit(1.0 - p)
    }
}

/// NF-k levels for `bits ∈ {2, 3, 4}`. For 4 we return the published NF4
/// codebook; for smaller widths we use the QLoRA construction: 2^(b-1)
/// negative quantiles, an exact zero, and 2^(b-1) − 1 positive quantiles,
/// normalized to [−1, 1].
pub fn nf_levels(bits: u32) -> Vec<f64> {
    assert!((2..=4).contains(&bits), "NF supported for 2..4 bits");
    if bits == 4 {
        return NF4_LEVELS.to_vec();
    }
    let n = 1usize << bits;
    let half_neg = n / 2; // negative side count
    let half_pos = n - half_neg - 1; // positive side count (zero takes a slot)
    let offset = 0.9677083; // QLoRA's quantile offset
    let mut levels = Vec::with_capacity(n);
    // Negative side: quantiles of (1-offset) .. 0.5 over half_neg+1 points.
    for i in 0..half_neg {
        let t = (1.0 - offset) + (0.5 - (1.0 - offset)) * (i as f64 / half_neg as f64);
        levels.push(probit(t));
    }
    levels.push(0.0);
    for i in 1..=half_pos {
        let t = 0.5 + (offset - 0.5) * (i as f64 / half_pos as f64);
        levels.push(probit(t));
    }
    // Normalize so extremes hit ±1.
    let max_abs = levels.iter().fold(0.0f64, |m, &x| m.max(x.abs()));
    for l in levels.iter_mut() {
        *l /= max_abs;
    }
    levels.sort_by(|a, b| a.partial_cmp(b).unwrap());
    levels
}

/// Block-wise NF-quantized tensor. Blocks run along the input dimension
/// (rows), mirroring the INT group layout.
#[derive(Clone, Debug)]
pub struct NfQuantized {
    pub bits: u32,
    pub block_size: usize,
    pub rows: usize,
    pub cols: usize,
    pub codes: Vec<u8>,
    /// num_blocks×n absmax scales.
    pub absmax: Matrix,
    pub levels: Vec<f64>,
}

impl NfQuantized {
    pub fn dequantize(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let b = i / self.block_size;
            for j in 0..self.cols {
                let c = self.codes[i * self.cols + j] as usize;
                out.set(i, j, self.levels[c] * self.absmax.at(b, j));
            }
        }
        out
    }
}

/// Nearest-level lookup (levels sorted ascending).
fn nearest_level(levels: &[f64], x: f64) -> u8 {
    let mut best = 0usize;
    let mut bd = f64::INFINITY;
    for (k, &l) in levels.iter().enumerate() {
        let d = (x - l).abs();
        if d < bd {
            bd = d;
            best = k;
        }
    }
    best as u8
}

/// NF-k quantization with per-(block, column) absmax scaling.
pub fn quantize_nf(w: &Matrix, bits: u32, block_size: usize) -> NfQuantized {
    let levels = nf_levels(bits);
    let (m, n) = (w.rows, w.cols);
    let bs = block_size.min(m).max(1);
    let num_blocks = m.div_ceil(bs);
    let mut codes = vec![0u8; m * n];
    let mut absmax = Matrix::zeros(num_blocks, n);
    for j in 0..n {
        for b in 0..num_blocks {
            let r0 = b * bs;
            let r1 = ((b + 1) * bs).min(m);
            let mut am = 0.0f64;
            for i in r0..r1 {
                am = am.max(w.at(i, j).abs());
            }
            if am == 0.0 {
                am = 1.0;
            }
            absmax.set(b, j, am);
            for i in r0..r1 {
                codes[i * n + j] = nearest_level(&levels, w.at(i, j) / am);
            }
        }
    }
    NfQuantized { bits, block_size: bs, rows: m, cols: n, codes, absmax, levels }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::norms::fro;
    use crate::util::prng::Rng;

    #[test]
    fn probit_known_values() {
        assert!((probit(0.5)).abs() < 1e-9);
        assert!((probit(0.975) - 1.959964).abs() < 1e-4);
        assert!((probit(0.025) + 1.959964).abs() < 1e-4);
        assert!((probit(0.8413447) - 1.0).abs() < 1e-4);
    }

    #[test]
    fn nf4_codebook_properties() {
        let l = nf_levels(4);
        assert_eq!(l.len(), 16);
        assert_eq!(l[0], -1.0);
        assert_eq!(*l.last().unwrap(), 1.0);
        assert!(l.contains(&0.0));
        for w in l.windows(2) {
            assert!(w[0] < w[1]);
        }
    }

    #[test]
    fn nf2_nf3_shapes() {
        for bits in [2u32, 3] {
            let l = nf_levels(bits);
            assert_eq!(l.len(), 1 << bits);
            assert!(l.iter().any(|&x| x == 0.0), "zero level required");
            assert!((l[0] + 1.0).abs() < 1e-9);
            assert!((l.last().unwrap() - 1.0).abs() < 1e-9);
            for w in l.windows(2) {
                assert!(w[0] < w[1]);
            }
        }
    }

    #[test]
    fn nf_quantize_roundtrip_error_small_for_gaussian() {
        let mut rng = Rng::new(40);
        let w = Matrix::randn(128, 8, 0.05, &mut rng);
        let q = quantize_nf(&w, 4, 64);
        let deq = q.dequantize();
        let rel = fro(&w.sub(&deq)) / fro(&w);
        // NF4 on Gaussian data: ~4% RMS relative error.
        assert!(rel < 0.12, "rel={rel}");
        // And it beats NF2 which beats nothing.
        let rel2 = fro(&w.sub(&quantize_nf(&w, 2, 64).dequantize())) / fro(&w);
        assert!(rel < rel2 && rel2 < 1.0, "rel={rel} rel2={rel2}");
    }

    #[test]
    fn nf_idempotent_on_grid() {
        let mut rng = Rng::new(41);
        let w = Matrix::randn(64, 4, 1.0, &mut rng);
        let d1 = quantize_nf(&w, 4, 32).dequantize();
        let d2 = quantize_nf(&d1, 4, 32).dequantize();
        assert!(d1.max_diff(&d2) < 1e-9);
    }

    #[test]
    fn absmax_value_representable_exactly() {
        // The max-|value| element of every block maps to ±1·absmax exactly.
        let mut rng = Rng::new(42);
        let w = Matrix::randn(32, 2, 1.0, &mut rng);
        let q = quantize_nf(&w, 4, 32);
        let deq = q.dequantize();
        for j in 0..2 {
            let (mut imax, mut vmax) = (0, 0.0f64);
            for i in 0..32 {
                if w.at(i, j).abs() > vmax {
                    vmax = w.at(i, j).abs();
                    imax = i;
                }
            }
            assert!((deq.at(imax, j) - w.at(imax, j)).abs() < 1e-9);
        }
    }
}

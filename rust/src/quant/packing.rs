//! Bit-packing of quantization codes for on-disk checkpoints and for the
//! packed-weights artifact consumed by the serving path.
//!
//! Codes are `b`-bit unsigned integers packed little-endian into `u32`
//! words (the layout the Pallas kernel's reference unpacker in
//! `python/compile/kernels/ref.py` mirrors — cross-checked by the golden
//! test `rust/tests/golden_quant.rs`).

/// Pack `codes` (each < 2^bits) into u32 words, little-endian bit order.
///
/// Panics (hard, in release too) on an out-of-range code: a code wider than
/// `bits` would silently corrupt the neighboring lanes of its word, and the
/// packed artifact is exactly the place such corruption must not reach.
pub fn pack_codes(codes: &[u8], bits: u32) -> Vec<u32> {
    assert!((1..=8).contains(&bits));
    let per_word = 32 / bits as usize;
    let mut out = Vec::with_capacity(codes.len().div_ceil(per_word));
    for chunk in codes.chunks(per_word) {
        let mut word = 0u32;
        for (k, &c) in chunk.iter().enumerate() {
            assert!((c as u32) < (1 << bits), "code {c} out of range for {bits} bits");
            word |= (c as u32) << (k as u32 * bits);
        }
        out.push(word);
    }
    out
}

/// Unpack `n` codes from packed u32 words, surfacing a short buffer as an
/// error instead of a panic — the artifact loader turns this into a
/// corruption diagnosis naming the offending layer.
pub fn try_unpack_codes(packed: &[u32], bits: u32, n: usize) -> anyhow::Result<Vec<u8>> {
    anyhow::ensure!((1..=8).contains(&bits), "bit width {bits} outside 1..=8");
    let per_word = 32 / bits as usize;
    anyhow::ensure!(
        packed.len() * per_word >= n,
        "packed buffer too short: {} words hold {} codes, need {n}",
        packed.len(),
        packed.len() * per_word,
    );
    let mask = ((1u64 << bits) - 1) as u32;
    let mut out = Vec::with_capacity(n);
    'outer: for &word in packed {
        for k in 0..per_word {
            if out.len() == n {
                break 'outer;
            }
            out.push(((word >> (k as u32 * bits)) & mask) as u8);
        }
    }
    Ok(out)
}

/// Unpack `n` codes from packed u32 words; panics on a short buffer (use
/// [`try_unpack_codes`] where the buffer comes from untrusted bytes).
pub fn unpack_codes(packed: &[u32], bits: u32, n: usize) -> Vec<u8> {
    try_unpack_codes(packed, bits, n).expect("unpack_codes")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn roundtrip_all_bitwidths() {
        let mut rng = Rng::new(80);
        for bits in 1..=8u32 {
            for &n in &[0usize, 1, 7, 31, 32, 33, 1000] {
                let codes: Vec<u8> =
                    (0..n).map(|_| rng.below(1 << bits) as u8).collect();
                let packed = pack_codes(&codes, bits);
                assert_eq!(unpack_codes(&packed, bits, n), codes, "bits={bits} n={n}");
            }
        }
    }

    #[test]
    fn packing_is_compact() {
        let codes = vec![1u8; 64];
        assert_eq!(pack_codes(&codes, 2).len(), 4); // 16 per word
        assert_eq!(pack_codes(&codes, 4).len(), 8); // 8 per word
        assert_eq!(pack_codes(&codes, 3).len(), 7); // 10 per word → ceil(64/10)
    }

    #[test]
    fn known_layout() {
        // 4-bit codes [1,2,3] → word 0x321.
        assert_eq!(pack_codes(&[1, 2, 3], 4), vec![0x321]);
        // 2-bit codes [3,0,1,2] → 0b10_01_00_11 = 0x93.
        assert_eq!(pack_codes(&[3, 0, 1, 2], 2), vec![0x93]);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn out_of_range_code_panics_in_release_too() {
        pack_codes(&[4], 2);
    }

    #[test]
    fn short_buffer_is_an_error_not_a_panic() {
        let packed = pack_codes(&[1u8; 20], 3); // 2 words (10 codes/word)
        let err = try_unpack_codes(&packed, 3, 21).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("too short"), "{msg}");
        assert!(msg.contains("need 21"), "{msg}");
        // Exactly-full buffers still work.
        assert_eq!(try_unpack_codes(&packed, 3, 20).unwrap(), vec![1u8; 20]);
    }
}

//! Bit-packing of quantization codes for on-disk checkpoints and for the
//! packed-weights artifact consumed by the serving path.
//!
//! Codes are `b`-bit unsigned integers packed little-endian into `u32`
//! words (the layout the Pallas kernel's reference unpacker in
//! `python/compile/kernels/ref.py` mirrors — cross-checked by the golden
//! test `rust/tests/golden_quant.rs`).

/// Pack `codes` (each < 2^bits) into u32 words, little-endian bit order.
pub fn pack_codes(codes: &[u8], bits: u32) -> Vec<u32> {
    assert!((1..=8).contains(&bits));
    let per_word = 32 / bits as usize;
    let mut out = Vec::with_capacity(codes.len().div_ceil(per_word));
    for chunk in codes.chunks(per_word) {
        let mut word = 0u32;
        for (k, &c) in chunk.iter().enumerate() {
            debug_assert!((c as u32) < (1 << bits), "code {c} out of range for {bits} bits");
            word |= (c as u32) << (k as u32 * bits);
        }
        out.push(word);
    }
    out
}

/// Unpack `n` codes from packed u32 words.
pub fn unpack_codes(packed: &[u32], bits: u32, n: usize) -> Vec<u8> {
    assert!((1..=8).contains(&bits));
    let per_word = 32 / bits as usize;
    let mask = ((1u64 << bits) - 1) as u32;
    let mut out = Vec::with_capacity(n);
    'outer: for &word in packed {
        for k in 0..per_word {
            if out.len() == n {
                break 'outer;
            }
            out.push(((word >> (k as u32 * bits)) & mask) as u8);
        }
    }
    assert_eq!(out.len(), n, "packed buffer too short");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn roundtrip_all_bitwidths() {
        let mut rng = Rng::new(80);
        for bits in 1..=8u32 {
            for &n in &[0usize, 1, 7, 31, 32, 33, 1000] {
                let codes: Vec<u8> =
                    (0..n).map(|_| rng.below(1 << bits) as u8).collect();
                let packed = pack_codes(&codes, bits);
                assert_eq!(unpack_codes(&packed, bits, n), codes, "bits={bits} n={n}");
            }
        }
    }

    #[test]
    fn packing_is_compact() {
        let codes = vec![1u8; 64];
        assert_eq!(pack_codes(&codes, 2).len(), 4); // 16 per word
        assert_eq!(pack_codes(&codes, 4).len(), 8); // 8 per word
        assert_eq!(pack_codes(&codes, 3).len(), 7); // 10 per word → ceil(64/10)
    }

    #[test]
    fn known_layout() {
        // 4-bit codes [1,2,3] → word 0x321.
        assert_eq!(pack_codes(&[1, 2, 3], 4), vec![0x321]);
        // 2-bit codes [3,0,1,2] → 0b10_01_00_11 = 0x93.
        assert_eq!(pack_codes(&[3, 0, 1, 2], 2), vec![0x93]);
    }
}

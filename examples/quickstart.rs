//! Quickstart: CLoQ on a single linear layer, no artifacts needed.
//!
//! Builds a synthetic "pretrained" weight matrix and correlated calibration
//! activations, then walks the exact steps of Algorithm 1:
//!
//!   1. H = XᵀX (+ λI)                    — calibration Gram matrix
//!   2. Q = OPTQ(MagR(W), H)              — calibrated 2-bit quantization
//!   3. (A, B) = closed-form Theorem 3.1  — two SVDs, no back-prop
//!
//! and prints the calibrated discrepancy ‖X(Q + A·Bᵀ − W)‖_F² of every
//! method it compares against (QLoRA / GPTQ-LoRA / LoftQ / CLoQ).
//!
//! Run: `cargo run --release --example quickstart`
//!
//! The next step after initialization is serving the frozen base + cheap
//! adapters: `examples/serve_demo.rs` walks the typed serving façade
//! (`ServeEngine::builder`, interned `LayerId`/`AdapterId`/`Route`
//! handles, the unified `ArtifactStore`, typed `ServeError` handling).

use cloq::linalg::{matmul, matmul_nt, syrk_t, Matrix};
use cloq::lowrank::{init_layer, InitConfig, Method};
use cloq::quant::metrics::calibrated_error2;
use cloq::util::prng::Rng;

fn main() {
    let mut rng = Rng::new(2025);

    // A 64→48 linear layer with correlated activations (b·l = 512 samples).
    let (m, n, samples) = (64usize, 48usize, 512usize);
    let base = Matrix::randn(samples, 16, 1.0, &mut rng);
    let mix = Matrix::randn(16, m, 1.0, &mut rng);
    let x = matmul(&base, &mix); // rank-16 activation structure
    let w = Matrix::randn(m, n, 0.3, &mut rng);
    let h = syrk_t(&x);

    println!("layer: W {m}x{n}, calibration X {samples}x{m} (effective rank 16)\n");
    println!("{:<14} {:>6} {:>16} {:>10}", "method", "bits", "||X*err||_F^2", "vs CLoQ");

    let bits = 2;
    let rank = 8;
    let mut results = Vec::new();
    for method in
        [Method::QLora, Method::GptqLora, Method::LoftQ, Method::CLoQNoMagR, Method::CLoQ]
    {
        let mut cfg = InitConfig::new(method, bits, rank);
        cfg.group_size = 32;
        let li = init_layer(&w, Some(&h), &cfg, &mut rng);
        let err = li.q_deq.add(&matmul_nt(&li.a, &li.b)).sub(&w);
        let obj = calibrated_error2(&h, &err);
        results.push((method.name().to_string(), obj));
    }
    let cloq_obj = results.last().unwrap().1;
    for (name, obj) in &results {
        println!("{name:<14} {bits:>6} {obj:>16.4} {:>9.2}x", obj / cloq_obj);
    }

    println!(
        "\nCLoQ's calibrated closed-form init cuts the layer discrepancy by\n\
         {:.1}x vs LoftQ and {:.1}x vs zero-init GPTQ-LoRA —\n\
         the paper's Fig. 2 effect, in one function call.",
        results[2].1 / cloq_obj,
        results[1].1 / cloq_obj
    );
    println!(
        "\nNext: serve the frozen base + adapters — \
         `cargo run --release --example serve_demo` (the typed serving façade)."
    );
}

//! Discrepancy study (the paper's Fig. 2, standalone): how the layer-wise
//! calibrated error ‖X(Q + A·Bᵀ − W)‖ falls with adapter rank, for CLoQ's
//! closed form vs LoftQ's data-free AltMin, in both the spectral and the
//! Frobenius norm.
//!
//! Works on a synthetic layer out of the box; pass `--artifacts` (and run
//! `make artifacts` + `cloq pretrain` first) to study a REAL pretrained
//! TinyGPT layer with its REAL calibration Gram matrix — that variant is
//! what `cloq fig 2` records to reports/fig2.json.
//!
//! Run: `cargo run --release --example discrepancy_study`

use cloq::linalg::norms::discrepancy_from_re;
use cloq::linalg::{matmul, syrk_t, Matrix};
use cloq::lowrank::{
    cloq_lowrank, damping_lambda, gram_root, loftq, CloqConfig, LoftqConfig, LoftqQuantizer,
};
use cloq::quant::magr::magr;
use cloq::quant::optq::{optq, OptqConfig};
use cloq::util::prng::Rng;

fn main() {
    let mut rng = Rng::new(7);
    let (m, n) = (96usize, 64usize);

    // Synthetic pretrained layer + anisotropic calibration activations.
    let base = Matrix::randn(768, 24, 1.0, &mut rng);
    let mix = Matrix::randn(24, m, 1.0, &mut rng);
    let x = matmul(&base, &mix);
    let w = Matrix::randn(m, n, 0.25, &mut rng);
    let h = syrk_t(&x);
    let mut hd = h.clone();
    hd.add_diag(damping_lambda(&h, 0.01));
    let root = gram_root(&hd, 1e-12);

    let bits = 2;
    let gs = 32;

    // CLoQ base: MagR + OPTQ once; rank only changes the low-rank step.
    let w_magr = magr(&w, &hd, &Default::default());
    let q_cloq =
        optq(&w_magr, &h, &OptqConfig { bits, group_size: gs, ..Default::default() }).dequantize();

    println!("INT{bits} layer {m}x{n}; discrepancy ||X(Q + AB' - W)|| vs rank\n");
    println!(
        "{:>5} | {:>12} {:>12} | {:>12} {:>12}",
        "rank", "CLoQ spec", "LoftQ spec", "CLoQ fro", "LoftQ fro"
    );
    println!("{}", "-".repeat(62));

    for r in [0usize, 1, 2, 4, 8, 16, 32] {
        let dw = w.sub(&q_cloq);
        let init = cloq_lowrank(&hd, &dw, &CloqConfig { rank: r, ..Default::default() });
        let e_cloq = q_cloq.add(&init.ab_t()).sub(&w);
        let d_cloq = discrepancy_from_re(&matmul(&root.r, &e_cloq));

        let lq = loftq(
            &w,
            &LoftqConfig {
                bits,
                group_size: gs,
                rank: r.max(1),
                iters: 5,
                quantizer: LoftqQuantizer::Int,
            },
        );
        let e_loftq = lq.q_deq.add(&lq.ab_t()).sub(&w);
        let d_loftq = discrepancy_from_re(&matmul(&root.r, &e_loftq));

        println!(
            "{r:>5} | {:>12.4} {:>12.4} | {:>12.4} {:>12.4}",
            d_cloq.spectral, d_loftq.spectral, d_cloq.frobenius, d_loftq.frobenius
        );
    }

    println!(
        "\nCLoQ minimizes the CALIBRATED error directly (Theorem 3.1), so both\n\
         curves drop far faster than LoftQ's, which minimizes ||Q + AB' - W||_F\n\
         without seeing X — the paper's Fig. 2."
    );
}

//! The HTTP front-end, end to end over a real loopback socket: boot a
//! quantized chain behind [`HttpServer`], then drive every endpoint with
//! a raw `std::net::TcpStream` client (no HTTP library on either side) —
//! tenant auth, a quota rejection, single-layer submits, a pipelined
//! burst on one keep-alive connection, the adapter lifecycle
//! (PUT register → POST hot-swap → DELETE unregister), a multi-step
//! session, a token-level generation (one JSON body, then the same
//! request streamed as chunked transfer-encoding, one NDJSON token
//! event per chunk), `/v1/stats`, and a `/metrics` Prometheus scrape.
//!
//! ```sh
//! cargo run --release --example serve_http
//! ```

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;

use cloq::linalg::Matrix;
use cloq::quant::{quantize_rtn, QuantState};
use cloq::serve::{HttpServer, PackedLayer, PackedModel, ServeEngine};
use cloq::util::prng::Rng;

const TOKEN: &str = "tok-acme";

/// Minimal raw-socket HTTP/1.1 client: write request bytes, frame
/// responses by `Content-Length`. This is the whole client a non-Rust
/// consumer needs.
struct Client {
    stream: TcpStream,
    buf: Vec<u8>,
}

impl Client {
    fn connect(addr: SocketAddr) -> anyhow::Result<Client> {
        Ok(Client { stream: TcpStream::connect(addr)?, buf: Vec::new() })
    }

    fn request(
        &mut self,
        method: &str,
        path: &str,
        token: Option<&str>,
        body: &str,
    ) -> anyhow::Result<(u16, String)> {
        let mut head = format!("{method} {path} HTTP/1.1\r\n");
        if let Some(t) = token {
            head.push_str(&format!("Authorization: Bearer {t}\r\n"));
        }
        head.push_str(&format!("Content-Length: {}\r\n\r\n", body.len()));
        self.stream.write_all(head.as_bytes())?;
        self.stream.write_all(body.as_bytes())?;
        self.recv()
    }

    fn recv(&mut self) -> anyhow::Result<(u16, String)> {
        let mut tmp = [0u8; 4096];
        loop {
            if let Some(pos) = self.buf.windows(4).position(|w| w == b"\r\n\r\n") {
                let head = String::from_utf8(self.buf[..pos].to_vec())?;
                let status: u16 = head.split(' ').nth(1).unwrap_or("0").parse()?;
                let cl = head
                    .lines()
                    .find_map(|l| {
                        let (k, v) = l.split_once(':')?;
                        k.eq_ignore_ascii_case("content-length")
                            .then(|| v.trim().parse::<usize>().ok())?
                    })
                    .unwrap_or(0);
                let start = pos + 4;
                while self.buf.len() < start + cl {
                    let n = self.stream.read(&mut tmp)?;
                    anyhow::ensure!(n > 0, "server closed mid-body");
                    self.buf.extend_from_slice(&tmp[..n]);
                }
                let body = String::from_utf8(self.buf[start..start + cl].to_vec())?;
                self.buf.drain(..start + cl);
                return Ok((status, body));
            }
            let n = self.stream.read(&mut tmp)?;
            anyhow::ensure!(n > 0, "server closed before a response");
            self.buf.extend_from_slice(&tmp[..n]);
        }
    }

    /// Read until `pat` appears; return everything through it.
    fn read_until(&mut self, pat: &[u8]) -> anyhow::Result<Vec<u8>> {
        let mut tmp = [0u8; 4096];
        loop {
            if let Some(pos) = self.buf.windows(pat.len()).position(|w| w == pat) {
                let end = pos + pat.len();
                let out = self.buf[..end].to_vec();
                self.buf.drain(..end);
                return Ok(out);
            }
            let n = self.stream.read(&mut tmp)?;
            anyhow::ensure!(n > 0, "server closed mid-stream");
            self.buf.extend_from_slice(&tmp[..n]);
        }
    }

    /// Frame a chunked transfer-encoding response: hex size line, payload,
    /// CRLF, repeated until the zero-length terminator. The connection
    /// stays usable afterwards — chunked framing is self-delimiting.
    fn recv_chunked(&mut self) -> anyhow::Result<(u16, Vec<String>)> {
        let head = String::from_utf8(self.read_until(b"\r\n\r\n")?)?;
        let status: u16 = head.split(' ').nth(1).unwrap_or("0").parse()?;
        anyhow::ensure!(
            head.to_ascii_lowercase().contains("transfer-encoding: chunked"),
            "expected a chunked response, got: {head}"
        );
        let mut tmp = [0u8; 4096];
        let mut chunks = Vec::new();
        loop {
            let line = self.read_until(b"\r\n")?;
            let hex = std::str::from_utf8(&line[..line.len() - 2])?;
            let len = usize::from_str_radix(hex, 16)?;
            while self.buf.len() < len + 2 {
                let n = self.stream.read(&mut tmp)?;
                anyhow::ensure!(n > 0, "server closed mid-chunk");
                self.buf.extend_from_slice(&tmp[..n]);
            }
            let payload = self.buf[..len].to_vec();
            self.buf.drain(..len + 2);
            if len == 0 {
                return Ok((status, chunks));
            }
            chunks.push(String::from_utf8(payload)?);
        }
    }
}

fn nums(xs: &[f64]) -> String {
    xs.iter().map(|v| format!("{v}")).collect::<Vec<_>>().join(",")
}

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(42);

    // ---- 1. a quantized 12→8→20→12 chain behind the HTTP front-end -------
    let mut layers = Vec::new();
    for (name, m, n) in [("a", 12usize, 8usize), ("b", 8, 20), ("c", 20, 12)] {
        let w = Matrix::randn(m, n, 0.3, &mut rng);
        let q = QuantState::Int(quantize_rtn(&w, 4, 8));
        layers.push(PackedLayer::from_state(name, &q)?);
    }
    let engine = Arc::new(
        ServeEngine::builder(PackedModel::new(layers)).workers(2).max_batch(8).build()?,
    );
    let server = HttpServer::builder(Arc::clone(&engine))
        .tenant("acme", TOKEN, 8) // 8 in-flight inference requests
        .tenant("metered", "tok-metered", 0) // 0 → every inference call is 429
        .build()?;
    let addr = server.addr();
    println!("== serve_http == listening on {addr} (loopback, OS-assigned port)");

    // ---- 2. auth + quota: rejected before the engine ever sees them -------
    let mut c = Client::connect(addr)?;
    let (status, body) = c.request("GET", "/v1/stats", None, "")?;
    println!("   no token        → {status} {body}");
    anyhow::ensure!(status == 401);
    let x12 = rng.gauss_vec(12);
    let submit = format!("{{\"layer\":\"a\",\"x\":[{}]}}", nums(&x12));
    let (status, body) = c.request("POST", "/v1/submit", Some("tok-metered"), &submit)?;
    println!("   quota 0 tenant  → {status} {body}");
    anyhow::ensure!(status == 429);

    // ---- 3. single-layer submit + a pipelined burst on ONE connection -----
    let (status, body) = c.request("POST", "/v1/submit", Some(TOKEN), &submit)?;
    anyhow::ensure!(status == 200, "submit failed: {body}");
    println!("   submit a        → {status} {} response bytes", body.len());
    // Four requests written back-to-back before reading a single response:
    // all four are in the engine concurrently; the rail answers in order.
    let mut burst = Vec::new();
    for _ in 0..4 {
        let x = rng.gauss_vec(12);
        let b = format!("{{\"layer\":\"a\",\"x\":[{}]}}", nums(&x));
        burst.extend_from_slice(
            format!(
                "POST /v1/submit HTTP/1.1\r\nAuthorization: Bearer {TOKEN}\r\n\
                 Content-Length: {}\r\n\r\n{b}",
                b.len()
            )
            .as_bytes(),
        );
    }
    c.stream.write_all(&burst)?;
    for k in 0..4 {
        let (status, _) = c.recv()?;
        anyhow::ensure!(status == 200, "pipelined response {k}");
    }
    println!("   pipelined burst → 4 requests, one write, 4 ordered 200s");

    // ---- 4. adapter lifecycle over the wire -------------------------------
    let (rank, rows, cols) = (2usize, 12usize, 8usize);
    let mk_body = |scale: f64| {
        let a: Vec<f64> = (0..rows * rank).map(|i| scale * (0.01 * i as f64 - 0.1)).collect();
        let b: Vec<f64> = (0..cols * rank).map(|i| scale * (0.02 - 0.009 * i as f64)).collect();
        format!(
            "{{\"layers\":[{{\"layer\":\"a\",\"rank\":{rank},\"a\":[{}],\"b\":[{}]}}]}}",
            nums(&a),
            nums(&b)
        )
    };
    let (status, body) = c.request("PUT", "/v1/adapters/t1", Some(TOKEN), &mk_body(1.0))?;
    println!("   PUT adapter     → {status} {body}");
    anyhow::ensure!(status == 200);
    let with_adapter = format!("{{\"layer\":\"a\",\"adapter\":\"t1\",\"x\":[{}]}}", nums(&x12));
    let (status, _) = c.request("POST", "/v1/submit", Some(TOKEN), &with_adapter)?;
    anyhow::ensure!(status == 200);
    let (status, body) = c.request("POST", "/v1/adapters/t1", Some(TOKEN), &mk_body(-0.5))?;
    println!("   hot-swap        → {status} {body}");
    anyhow::ensure!(status == 200);
    let (status, body) = c.request("DELETE", "/v1/adapters/t1", Some(TOKEN), "")?;
    println!("   DELETE adapter  → {status} {body}");
    anyhow::ensure!(status == 200);
    let (status, body) = c.request("POST", "/v1/submit", Some(TOKEN), &with_adapter)?;
    println!("   stale adapter   → {status} {body} (typed, over the wire)");
    anyhow::ensure!(status == 404);

    // ---- 5. a 3-step session on the loopable chain ------------------------
    let session = format!(
        "{{\"route\":[\"a\",\"b\",\"c\"],\"x\":[{}],\"steps\":3}}",
        nums(&x12)
    );
    let (status, body) = c.request("POST", "/v1/session", Some(TOKEN), &session)?;
    anyhow::ensure!(status == 200, "session failed: {body}");
    println!("   3-step session  → {status} {} response bytes", body.len());

    // ---- 5b. token-level generation: one JSON body, then a chunked stream -
    let gen = "{\"route\":[\"a\",\"b\",\"c\"],\"prompt\":\"Q: 2+2?\",\"max_tokens\":6}";
    let (status, body) = c.request("POST", "/v1/generate", Some(TOKEN), gen)?;
    anyhow::ensure!(status == 200, "generate failed: {body}");
    println!(
        "   generate        → {status} {} bytes (text, token ids, finish reason, ttft)",
        body.len()
    );
    // The same request with "stream": true answers with chunked
    // transfer-encoding: every chunk is one NDJSON line — a token event
    // as it decodes, then the full response record flagged "done".
    let gen_stream =
        "{\"route\":[\"a\",\"b\",\"c\"],\"prompt\":\"Q: 2+2?\",\"max_tokens\":6,\"stream\":true}";
    c.stream.write_all(
        format!(
            "POST /v1/generate HTTP/1.1\r\nAuthorization: Bearer {TOKEN}\r\n\
             Content-Length: {}\r\n\r\n{gen_stream}",
            gen_stream.len()
        )
        .as_bytes(),
    )?;
    let (status, chunks) = c.recv_chunked()?;
    anyhow::ensure!(status == 200);
    anyhow::ensure!(
        chunks.last().is_some_and(|l| l.contains("\"done\":true")),
        "the final chunk must be the done record"
    );
    println!(
        "   generate stream → {status} chunked: {} token events + 1 done record",
        chunks.len() - 1
    );

    // ---- 6. observability: /v1/stats (tenant) + /metrics (scraper) --------
    let (status, body) = c.request("GET", "/v1/stats", Some(TOKEN), "")?;
    anyhow::ensure!(status == 200);
    println!("   /v1/stats       → {body}");
    let (status, prom) = c.request("GET", "/metrics", None, "")?;
    anyhow::ensure!(status == 200);
    let shown: Vec<&str> =
        prom.lines().filter(|l| l.starts_with("cloq_http_")).take(6).collect();
    println!("   /metrics        → {} bytes; http counters:", prom.len());
    for line in &shown {
        println!("      {line}");
    }

    server.shutdown();
    drop(c);
    let stats = match Arc::try_unwrap(engine) {
        Ok(e) => e.shutdown(),
        Err(_) => anyhow::bail!("server kept an engine handle after shutdown"),
    };
    println!(
        "\n== totals == {} singles + {} model/session requests in {} micro-batches",
        stats.requests, stats.model_requests, stats.batches
    );
    println!("\nserve_http: OK");
    Ok(())
}

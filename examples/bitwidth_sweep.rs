//! Bit-width sweep: calibrated quantization error of the full method stack
//! (RTN → OPTQ → MagR+OPTQ → MagR+OPTQ+CLoQ-rank-r) across INT2/3/4/8 —
//! the ablation behind DESIGN.md's "who contributes what at which bit".
//!
//! Run: `cargo run --release --example bitwidth_sweep`

use cloq::linalg::{matmul, matmul_nt, syrk_t, Matrix};
use cloq::lowrank::{cloq_lowrank, damping_lambda, CloqConfig};
use cloq::quant::magr::magr;
use cloq::quant::metrics::calibrated_error2;
use cloq::quant::optq::{optq, OptqConfig};
use cloq::quant::quantize_rtn;
use cloq::util::prng::Rng;

fn main() {
    let mut rng = Rng::new(11);
    let (m, n, gs, rank) = (96usize, 64usize, 32usize, 8usize);

    // Outlier-heavy weights + low-rank activations: the regime where each
    // pipeline stage earns its keep.
    let base = Matrix::randn(768, 20, 1.0, &mut rng);
    let mix = Matrix::randn(20, m, 1.0, &mut rng);
    let x = matmul(&base, &mix);
    let mut w = Matrix::randn(m, n, 0.15, &mut rng);
    for _ in 0..24 {
        let (i, j) = (rng.below(m), rng.below(n));
        w.set(i, j, rng.normal(0.0, 1.2));
    }
    let h = syrk_t(&x);
    let mut hd = h.clone();
    hd.add_diag(damping_lambda(&h, 0.01));

    let err = |q_deq: &Matrix, ab: Option<&Matrix>| {
        let mut e = q_deq.sub(&w);
        if let Some(ab) = ab {
            e.add_assign(ab);
        }
        calibrated_error2(&h, &e)
    };

    println!(
        "calibrated error ||X(Q [+AB'] - W)||_F^2 by stage (layer {m}x{n}, group {gs}, \
         rank {rank})\n"
    );
    println!(
        "{:>4} | {:>12} {:>12} {:>12} {:>14}",
        "bits", "RTN", "OPTQ", "MagR+OPTQ", "+CLoQ rank-8"
    );
    println!("{}", "-".repeat(62));
    for bits in [2u32, 3, 4, 8] {
        let e_rtn = err(&quantize_rtn(&w, bits, gs).dequantize(), None);
        let ocfg = OptqConfig { bits, group_size: gs, ..Default::default() };
        let e_optq = err(&optq(&w, &h, &ocfg).dequantize(), None);
        let w_magr = magr(&w, &hd, &Default::default());
        let q_magr = optq(&w_magr, &h, &ocfg).dequantize();
        let e_magr = err(&q_magr, None);
        let dw = w.sub(&q_magr);
        let lr = cloq_lowrank(&hd, &dw, &CloqConfig { rank, ..Default::default() });
        let ab = matmul_nt(&lr.a, &lr.b);
        let e_cloq = err(&q_magr, Some(&ab));
        println!("{bits:>4} | {e_rtn:>12.3} {e_optq:>12.3} {e_magr:>12.3} {e_cloq:>14.3}");
    }

    println!(
        "\nReading the rows: OPTQ beats RTN everywhere; MagR matters most at\n\
         2-bit where grid resolution is scarce; the CLoQ correction removes\n\
         the bulk of what is left — and its share GROWS as bits shrink,\n\
         which is exactly why the paper's gains concentrate at INT2."
    );
}

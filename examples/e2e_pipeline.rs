//! END-TO-END DRIVER (EXPERIMENTS.md §E2E): the full system on a real
//! small workload, proving all layers compose —
//!
//!   1. Pretrain TinyGPT from scratch on the synthetic corpus via the AOT
//!      `pretrain_step` graph (loss curve logged below).
//!   2. Calibrate: stream 128 sequences through `capture_grams`
//!      (the L1 Pallas gram kernel) and accumulate per-layer H = XᵀX.
//!   3. Quantize every linear with MagR + OPTQ at INT2 (L3 numerics).
//!   4. Initialize LoRA adapters with CLoQ's closed form (Theorem 3.1).
//!   5. Fine-tune the adapters on s-Math10K via the `lora_step` graph.
//!   6. Evaluate: arithmetic accuracy + corpus perplexity, and run the
//!      quantized serving path (`qeval_loss` through the L1 fused
//!      dequant-matmul Pallas kernel) to verify it agrees with the dense
//!      eval on the same weights.
//!
//! Needs `make artifacts` first. Run: `make e2e`
//! (or `cargo run --release --example e2e_pipeline`).

use std::path::PathBuf;

use cloq::coordinator::{
    ensure_grams, finetune_lora, perplexity, pretrain, task_accuracy, DataSource, TrainConfig,
};
use cloq::coordinator::pipeline::{init_model, FinetuneTask, PipelineOpts, RunSpec};
use cloq::data::{math10k, Split, ARITH_TASKS};
use cloq::lowrank::Method;
use cloq::model::init_base;
use cloq::runtime::{Runtime, Tensor};
use cloq::util::prng::Rng;

fn main() -> anyhow::Result<()> {
    let config = std::env::args().nth(1).unwrap_or_else(|| "tiny-s".to_string());
    let opts = PipelineOpts::new(&config);
    anyhow::ensure!(
        opts.artifacts.join("manifest.json").exists(),
        "artifacts/{config} missing — run `make artifacts` first"
    );
    let mut rt = Runtime::load(&opts.artifacts)?;
    let mcfg = rt.manifest.config.clone();
    println!(
        "== e2e: {} (d={} L={} heads={} ff={} seq={} rank={}) ==\n",
        mcfg.name, mcfg.d_model, mcfg.n_layers, mcfg.n_heads, mcfg.d_ff, mcfg.seq, mcfg.rank
    );

    // -- 1. pretrain from scratch ------------------------------------
    let steps = std::env::var("E2E_PRETRAIN_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1500usize);
    let mut rng = Rng::new(opts.seed);
    let init0 = init_base(&rt.manifest, &mut rng)?;
    let n_params: usize = init0.numel();
    println!("[1/6] pretraining {n_params} params for {steps} steps on the synthetic corpus");
    let tcfg = TrainConfig { steps, lr: 2e-3, weight_decay: 0.01, warmup_frac: 0.05, log_every: 0 };
    let (base, outcome) = pretrain(&mut rt, &init0, &tcfg, opts.seed)?;
    print!("      loss curve:");
    for (i, l) in outcome.losses.iter().enumerate() {
        if i % (steps / 12).max(1) == 0 || i + 1 == outcome.losses.len() {
            print!(" {l:.2}");
        }
    }
    println!("  (start {:.2} -> final {:.2})", outcome.losses[0], outcome.final_loss);
    anyhow::ensure!(
        outcome.final_loss < outcome.losses[0] - 0.5,
        "pretraining failed to learn"
    );

    // -- 2. calibrate --------------------------------------------------
    println!("[2/6] calibrating on {} sequences (Pallas gram kernel)", opts.calib_samples);
    std::fs::create_dir_all(&opts.runs_dir)?;
    base.save(&opts.runs_dir.join("e2e_base.ckpt"))?;
    let grams = ensure_grams(&mut rt, &base, &opts, opts.calib_samples)?;

    // -- 3+4. quantize + CLoQ init -------------------------------------
    println!("[3/6] MagR+OPTQ INT2 quantization of {} linears", mcfg.all_linear_names().len());
    let spec = RunSpec::new(Method::CLoQ, 2, FinetuneTask::Math10k);
    let (minit, init_secs) = init_model(&rt, &base, &grams, &spec)?;
    println!(
        "[4/6] CLoQ closed-form LoRA init done in {init_secs:.2}s ({:.2} bits/weight)",
        minit.bits_per_weight
    );

    // Baseline metrics before fine-tuning.
    let zero_lora = &minit.lora; // CLoQ init (not zero — that's the point)
    let test_sets: Vec<_> = ARITH_TASKS
        .iter()
        .map(|t| (t.name(), t.dataset(opts.eval_examples, spec.seed, 1)))
        .collect();

    // -- 5. LoRA fine-tune ---------------------------------------------
    let ft_steps = std::env::var("E2E_FT_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(250usize);
    println!("[5/6] fine-tuning LoRA adapters on s-Math10K for {ft_steps} steps");
    let data = math10k(opts.train_examples, spec.seed);
    let ftcfg = TrainConfig {
        steps: ft_steps,
        lr: spec.lr,
        weight_decay: spec.weight_decay,
        warmup_frac: 0.05,
        log_every: 0,
    };
    let (lora, ft) = finetune_lora(
        &mut rt,
        &minit.base_q,
        zero_lora,
        DataSource::Tasks(&data),
        &ftcfg,
        spec.seed,
    )?;
    println!(
        "      train loss {:.3} -> {:.3}",
        ft.losses[0],
        ft.final_loss
    );

    // -- 6. evaluate -----------------------------------------------------
    println!("[6/6] evaluation");
    let ppl =
        perplexity(&mut rt, &minit.base_q, &lora, opts.seed, Split::Valid, opts.eval_ppl_batches)?;
    println!("      corpus perplexity (INT2 base + CLoQ-finetuned LoRA): {ppl:.2}");
    let mut total = 0.0;
    for (name, set) in &test_sets {
        let acc = task_accuracy(&mut rt, &minit.base_q, &lora, set)?;
        println!("      {name:<10} accuracy: {:.1}%", acc * 100.0);
        total += acc;
    }
    println!("      arithmetic average: {:.1}%", 100.0 * total / test_sets.len() as f64);

    // Serving-path check: qeval (Pallas fused dequant kernel) vs dense.
    let qspec = rt.manifest.entry("qeval_loss")?.clone();
    let test_batch = {
        let text = cloq::data::corpus_text(opts.seed, Split::Test, 16 * mcfg.seq);
        let mut s = cloq::data::LmStream::new(&text, mcfg.batch, mcfg.seq);
        s.next_batch().unwrap()
    };
    let mut dense_inputs = minit.base_q.in_order();
    dense_inputs.extend(lora.in_order());
    dense_inputs.push(test_batch.tokens.clone());
    dense_inputs.push(test_batch.mask.clone());
    let dense = rt.run("eval_loss", &dense_inputs)?;

    let mut qinputs: Vec<Tensor> = Vec::new();
    for s in &qspec.inputs {
        if s.name == "tokens" {
            qinputs.push(test_batch.tokens.clone());
        } else if s.name == "mask" {
            qinputs.push(test_batch.mask.clone());
        } else if lora.contains(&s.name) {
            qinputs.push(lora.get(&s.name).clone());
        } else if minit.quant.contains(&s.name) {
            qinputs.push(minit.quant.get(&s.name).clone());
        } else {
            qinputs.push(minit.base_q.get(&s.name).clone());
        }
    }
    let qd = rt.run("qeval_loss", &qinputs)?;
    let (d, q) = (dense[0].scalar(), qd[0].scalar());
    println!(
        "      serving path (Pallas fused dequant kernel) loss {q:.4} vs dense {d:.4}  ({} ok)",
        if (d - q).abs() < 2e-2 * d.abs().max(1.0) { "agreement" } else { "MISMATCH" }
    );
    anyhow::ensure!(
        (d - q).abs() < 5e-2 * d.abs().max(1.0),
        "serving path disagrees with dense path"
    );

    println!(
        "\ne2e complete: all three layers composed (L3 rust loop -> L2 HLO graphs -> L1 \
         Pallas kernels)."
    );
    let _ = PathBuf::new();
    Ok(())
}

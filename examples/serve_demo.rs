//! End-to-end multi-tenant serving demo on the TYPED serving façade:
//! quantize + init a few layers, pack the base ONCE, ship per-tenant
//! adapter artifacts separately through the unified [`ArtifactStore`],
//! reload everything by magic-autodetecting `open`, intern the layer /
//! adapter / route handles once, and serve a mixed-adapter burst through
//! the batching engine — with a hot-swap, an unregister drain, and typed
//! error handling along the way. Also exercises the legacy v1 artifact
//! path (`Artifact::LegacyV1`), runs a token-level generation (prefill +
//! greedy decode through the same batcher, streamed token by token and
//! checked bit-for-bit against `generate_serial`), and closes with the
//! engine's telemetry snapshot: latency percentiles, per-adapter
//! attribution, one captured request-span timeline, and a Prometheus
//! exposition excerpt.
//!
//! ```sh
//! cargo run --release --example serve_demo
//! ```

use cloq::linalg::{syrk_t, Matrix};
use cloq::lowrank::{init_layer, InitConfig, LoraPair, Method};
use cloq::serve::{
    forward_route_serial, generate_serial, AdapterSet, Artifact, ArtifactStore, GenEvent,
    GenParams, GenRequest, Metric, ModelRequest, PackedLayer, PackedModel, Request, ServeEngine,
    ServeError, SessionRequest, StepFn, TelemetryOptions,
};
use cloq::util::prng::Rng;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(42);

    // ---- 1. quantize + init three layers; split base from adapters -------
    println!("== init: CLoQ / GPTQ-LoRA / QLoRA layers, base/adapter split ==");
    let mut layers = Vec::new();
    let mut init_pairs = Vec::new();
    let mut dense_refs = Vec::new();
    for (name, method, m, n) in [
        ("blk0.wq", Method::CLoQ, 96usize, 64usize),
        ("blk0.wo", Method::GptqLora, 64, 96),
        ("blk0.ffn", Method::QLora, 96, 128),
    ] {
        let w = Matrix::randn(m, n, 0.3, &mut rng);
        let x_cal = Matrix::randn(2 * m, m, 1.0, &mut rng);
        let h = syrk_t(&x_cal);
        let mut cfg = InitConfig::new(method, 3, 8);
        cfg.group_size = 32;
        let li = init_layer(&w, Some(&h), &cfg, &mut rng);
        let (layer, pair) = PackedLayer::from_layer_init(name, method, &li)?;
        println!(
            "  {name:<10} {m:>3}x{n:<3} {} → {:>6} base bytes + {:>5} adapter bytes \
             ({:.2} bits/weight)",
            method.name(),
            layer.packed_bytes(),
            pair.bytes(),
            li.bits_per_weight,
        );
        dense_refs.push((name.to_string(), li.q_deq.clone()));
        init_pairs.push((name.to_string(), pair));
        layers.push(layer);
    }
    let model = PackedModel::new(layers);
    let tenant_a = AdapterSet::from_pairs("tenant-a", init_pairs)?;
    // Two more tenants over the SAME base (stand-ins for task-finetuned
    // adapters): fresh pairs per layer.
    let mk_tenant = |id: &str, rng: &mut Rng| -> anyhow::Result<AdapterSet> {
        let mut set = AdapterSet::new(id);
        for l in &model.layers {
            let pair = LoraPair::new(
                Matrix::randn(l.rows, 8, 0.05, rng),
                Matrix::randn(l.cols, 8, 0.05, rng),
            );
            set.insert(&l.name, pair)?;
        }
        Ok(set)
    };
    let tenant_b = mk_tenant("tenant-b", &mut rng)?;
    let tenant_c = mk_tenant("tenant-c", &mut rng)?;

    // ---- 2. artifacts: one store, base once, adapters separately ----------
    let store = ArtifactStore::at(
        std::env::temp_dir().join(format!("cloq_serve_demo_{}", std::process::id())),
    );
    let base_path = store.save_base(&model, "base.cloqpkd2")?;
    let mut adapter_names = Vec::new();
    for set in [&tenant_a, &tenant_b, &tenant_c] {
        let name = format!("{}.cloqadp", set.id());
        store.save_adapter(set, &name)?;
        adapter_names.push(name);
    }
    let base_bytes = std::fs::metadata(&base_path)?.len();
    let adp_bytes: u64 = adapter_names
        .iter()
        .map(|n| std::fs::metadata(store.path(n)).map(|m| m.len()).unwrap_or(0))
        .sum();
    println!(
        "\n== artifacts == base shipped once: {base_bytes} bytes; \
         3 tenant artifacts: {adp_bytes} bytes total"
    );
    let loaded = store.load_base("base.cloqpkd2")?;

    // Legacy v1 files still open through the SAME entry point: the magic
    // bytes decide, and the embedded adapters come back as a set.
    store.save_legacy_v1(&model, &tenant_a, "legacy.cloqpkd")?;
    let (v1_model, v1_set) = match store.open("legacy.cloqpkd")? {
        Artifact::LegacyV1 { model, adapters } => (model, adapters),
        other => anyhow::bail!("expected a legacy artifact, found {}", other.kind_name()),
    };
    println!(
        "   v1 legacy open: {} layers + adapter set '{}' from the old format",
        v1_model.layers.len(),
        v1_set.id()
    );

    // Parity spot-check: packed fused forward vs the dense q_deq reference,
    // through the artifact roundtrip AND the legacy path.
    let mut max_ulp = 0u64;
    for (name, q_deq) in &dense_refs {
        let layer = loaded.layer(name).expect("layer survived the roundtrip");
        let pair = tenant_a.get(name);
        let x = rng.gauss_vec(layer.rows);
        let fused = layer.forward(&x, pair);
        let dense = layer.dense_reference_forward(q_deq, &x, pair);
        let shim = v1_model.layer(name).unwrap().forward(&x, v1_set.get(name));
        for ((u, v), s) in fused.iter().zip(&dense).zip(&shim) {
            max_ulp = max_ulp.max(u.to_bits().abs_diff(v.to_bits()));
            max_ulp = max_ulp.max(u.to_bits().abs_diff(s.to_bits()));
        }
    }
    println!("   fused vs dense vs v1-legacy, max ULP distance: {max_ulp} (contract: 0)");
    anyhow::ensure!(max_ulp == 0, "parity contract violated");

    // ---- 3. serve a concurrent multi-tenant burst -------------------------
    let reference = loaded.clone(); // serial-reference copy for §4's parity check
    // A zero slow-threshold captures EVERY request's span timeline into
    // the slow ring so §5 has a trace to show; the logger is muted to
    // Error because each "slow" capture would otherwise warn — dozens of
    // lines a real deployment only sees for genuinely slow requests.
    cloq::util::logging::set_level(cloq::util::logging::Level::Error);
    let engine = ServeEngine::builder(loaded)
        .workers(2)
        .max_batch(16)
        .telemetry(TelemetryOptions::default().slow_threshold_s(0.0).slow_traces(4))
        .build()?;
    // Intern once: every name becomes a Copy handle; the submission loop
    // below never hashes or clones a string.
    let mut tenant_ids = Vec::new();
    for name in &adapter_names {
        let set = store.open(name)?.into_adapter()?;
        tenant_ids.push(engine.register_adapter(set)?.id);
    }
    println!("\n== engine == tenants registered: {:?}", engine.registry().ids());
    let names: Vec<String> = dense_refs.iter().map(|(n, _)| n.clone()).collect();
    let layer_ids: Vec<_> =
        names.iter().map(|n| engine.layer(n)).collect::<Result<_, _>>()?;
    let reqs: Vec<Request> = (0..48)
        .map(|i| {
            let lid = layer_ids[i % layer_ids.len()];
            let rows = engine.model().get(lid).unwrap().rows;
            Request::with_adapter(lid, tenant_ids[i % tenant_ids.len()], rng.gauss_vec(rows))
        })
        .collect();
    let tickets = engine.submit_all(reqs);
    let mut worst_latency = 0.0f64;
    for t in tickets {
        let resp = t.wait()?;
        worst_latency = worst_latency.max(resp.queue_s + resp.compute_s);
    }

    // Hot-swap tenant-b under load (the interned id survives the swap),
    // then retire tenant-c with a drain — and show the TYPED rejection a
    // stale tenant gets afterwards.
    engine.register_adapter(mk_tenant("tenant-b", &mut rng)?)?;
    let x = rng.gauss_vec(engine.model().get(layer_ids[0]).unwrap().rows);
    engine.submit(layer_ids[0], Some(tenant_ids[1]), x).wait()?;
    engine.unregister_adapter("tenant-c")?;
    let stale = rng.gauss_vec(engine.model().get(layer_ids[0]).unwrap().rows);
    match engine.submit(layer_ids[0], Some(tenant_ids[2]), stale).wait() {
        Err(ServeError::UnknownAdapter { adapter }) => {
            println!(
                "   hot-swapped tenant-b, drained + retired tenant-c → now {:?} \
                 (stale submit rejected as UnknownAdapter('{adapter}'))",
                engine.registry().ids()
            );
        }
        other => anyhow::bail!("expected UnknownAdapter for the retired tenant, got {other:?}"),
    }

    // ---- 4. full-model pipelined forwards + a decode-style session --------
    // One ModelRequest walks the whole 96→64→96→128 chain through the
    // batcher: hops from concurrent requests at the same depth coalesce.
    // The route is resolved + chain-validated ONCE; per-request submission
    // clones an Arc, not a Vec<String>. The caller-driven serial reference
    // must match bit-for-bit.
    let route = engine.route(&names)?;
    let serial_route = reference.route(&names)?;
    let x0s: Vec<Vec<f64>> = (0..8).map(|_| rng.gauss_vec(96)).collect();
    let model_tickets: Vec<_> = x0s
        .iter()
        .map(|x| {
            engine.submit_model(ModelRequest::with_adapter(route.clone(), tenant_ids[0], x.clone()))
        })
        .collect();
    let mut fwd_ulp = 0u64;
    let mut max_hop_batch = 0usize;
    for (x, t) in x0s.iter().zip(model_tickets) {
        let resp = t.wait()?;
        let serial = forward_route_serial(&reference, &serial_route, Some(&tenant_a), x);
        for (u, v) in resp.y.iter().zip(&serial) {
            fwd_ulp = fwd_ulp.max(u.to_bits().abs_diff(v.to_bits()));
        }
        max_hop_batch = max_hop_batch.max(resp.max_batch_seen);
    }
    println!(
        "\n== pipelined forward == 8 model requests x {} hops, \
         max ULP vs serial reference: {fwd_ulp} (contract: 0), \
         largest coalesced hop batch: {max_hop_batch}",
        route.len()
    );
    anyhow::ensure!(fwd_ulp == 0, "pipelined forward parity violated");
    // A 3-step session (the autoregressive-decode shape): the step fn
    // bridges the 128-wide chain output back to the 96-wide head.
    let step_of = |y: &[f64]| -> Vec<f64> { y.iter().take(96).map(|v| v * 0.1).collect() };
    let step: StepFn = Box::new(move |_, y| Some(step_of(y)));
    let sess = engine
        .submit_session(SessionRequest::with_adapter(
            route.clone(),
            tenant_ids[0],
            x0s[0].clone(),
            3,
            step,
        ))
        .wait()?;
    let mut x = x0s[0].clone();
    let mut serial = Vec::new();
    for _ in 0..3 {
        serial = forward_route_serial(&reference, &serial_route, Some(&tenant_a), &x);
        x = serial.iter().take(96).map(|v| v * 0.1).collect();
    }
    let sess_ulp = sess
        .y
        .iter()
        .zip(&serial)
        .fold(0u64, |m, (u, v)| m.max(u.to_bits().abs_diff(v.to_bits())));
    println!(
        "   session: {} forwards, {} hops, {:.1} us queued / {:.1} us compute, \
         max ULP vs stepped serial: {sess_ulp} (contract: 0)",
        sess.forwards,
        sess.hops,
        sess.queue_s * 1e6,
        sess.compute_s * 1e6
    );
    anyhow::ensure!(sess_ulp == 0, "session parity violated");

    // ---- 4b. token-level generation (autoregressive decode) ---------------
    // generate() owns the whole loop the session above delegated to a step
    // fn: tokenize the prompt, prefill, then per token logits → greedy
    // sample → append → re-enter the batcher. Tokens stream out as they
    // decode; the caller-driven `generate_serial` reference must produce
    // the same token ids and bit-identical final logits.
    let prompt = "Q: what does CLoQ serve?";
    let gparams = GenParams::greedy(12);
    let ticket = engine.generate(GenRequest::with_adapter(
        route.clone(),
        tenant_ids[0],
        prompt,
        gparams.clone(),
    ));
    let mut pieces = String::new();
    let gen = loop {
        match ticket.next_token().wait()? {
            GenEvent::Token { piece, .. } => pieces.push_str(&piece),
            GenEvent::Done(r) => break r,
        }
    };
    let gen_serial = generate_serial(&reference, &serial_route, Some(&tenant_a), prompt, &gparams);
    let gen_ulp = gen
        .y
        .iter()
        .zip(&gen_serial.y)
        .fold(0u64, |m, (u, v)| m.max(u.to_bits().abs_diff(v.to_bits())));
    anyhow::ensure!(gen.tokens == gen_serial.tokens, "decode chose different tokens");
    anyhow::ensure!(pieces == gen.text, "streamed pieces must concatenate to the text");
    println!(
        "   generate: {} prompt + {} decoded tokens → {:?} ({}), ttft {:.1} us, \
         max ULP vs serial decode: {gen_ulp} (contract: 0)",
        gen.prompt_tokens,
        gen.tokens.len(),
        gen.text,
        gen.finish.as_str(),
        gen.ttft_s * 1e6
    );
    anyhow::ensure!(gen_ulp == 0, "decode parity violated");

    // ---- 5. telemetry: percentiles, attribution, a trace, Prometheus ----
    // Snapshot before shutdown: `telemetry()` borrows the live engine.
    let snap = engine.telemetry();
    println!(
        "\n== telemetry == hop latency p50/p95 {:.1}/{:.1} us, \
         request wall p50/p95 {:.1}/{:.1} us, batch compute p95 {:.1} us \
         (log-linear buckets, <=25% resolution)",
        snap.hist(Metric::HopLatency).quantile(0.5) * 1e6,
        snap.hist(Metric::HopLatency).quantile(0.95) * 1e6,
        snap.hist(Metric::RequestWall).quantile(0.5) * 1e6,
        snap.hist(Metric::RequestWall).quantile(0.95) * 1e6,
        snap.hist(Metric::BatchCompute).quantile(0.95) * 1e6,
    );
    for a in snap.per_adapter.iter().filter(|a| a.hops > 0) {
        println!(
            "   adapter {:<10} {:>4} hops  {:>8.1} us queued  {:>8.1} us compute",
            a.name,
            a.hops,
            a.queue_s * 1e6,
            a.compute_s * 1e6
        );
    }
    if let Some(trace) = snap.slow_traces.last() {
        println!("   captured span timeline (newest slow-ring entry):");
        for line in trace.render().lines() {
            println!("      {line}");
        }
    }
    let prom = snap.render_prometheus();
    println!(
        "   Prometheus exposition: {} bytes; first sample lines:",
        prom.len()
    );
    for line in prom.lines().filter(|l| !l.starts_with('#')).take(6) {
        println!("      {line}");
    }

    let stats = engine.shutdown();
    println!(
        "\n== totals == {} single requests + {} model/session requests \
         ({} forwards, {} hops) in {} micro-batches (mean batch {:.1}, max {}, mixed {})",
        stats.requests,
        stats.model_requests,
        stats.session_forwards,
        stats.hops,
        stats.batches,
        stats.mean_batch(),
        stats.max_batch_seen,
        stats.mixed_batches
    );
    println!(
        "   mean queue wait {:.1} us, worst request latency {:.1} us",
        stats.mean_queue_s() * 1e6,
        worst_latency * 1e6
    );

    std::fs::remove_dir_all(store.dir()).ok();
    println!("\nserve_demo: OK");
    Ok(())
}

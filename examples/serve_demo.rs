//! End-to-end serving demo: quantize + init a few layers, pack them, save
//! the versioned artifact, reload it, and serve a burst of concurrent
//! requests through the batching engine.
//!
//! ```sh
//! cargo run --release --example serve_demo
//! ```

use cloq::linalg::{syrk_t, Matrix};
use cloq::lowrank::{init_layer, InitConfig, Method};
use cloq::serve::{
    load_artifact, save_artifact, EngineConfig, PackedLayer, PackedModel, ServeEngine,
};
use cloq::util::prng::Rng;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(42);

    // ---- 1. quantize + init three layers with different methods ----------
    println!("== init: CLoQ / GPTQ-LoRA / QLoRA layers ==");
    let mut layers = Vec::new();
    let mut dense_refs = Vec::new();
    for (name, method, m, n) in [
        ("blk0.wq", Method::CLoQ, 96usize, 64usize),
        ("blk0.wo", Method::GptqLora, 64, 96),
        ("blk0.ffn", Method::QLora, 96, 128),
    ] {
        let w = Matrix::randn(m, n, 0.3, &mut rng);
        let x_cal = Matrix::randn(2 * m, m, 1.0, &mut rng);
        let h = syrk_t(&x_cal);
        let mut cfg = InitConfig::new(method, 3, 8);
        cfg.group_size = 32;
        let li = init_layer(&w, Some(&h), &cfg, &mut rng);
        let layer = PackedLayer::from_layer_init(name, method, &li)?;
        println!(
            "  {name:<10} {m:>3}x{n:<3} {} → {:>6} packed bytes ({:.2} bits/weight)",
            method.name(),
            layer.packed_bytes(),
            li.bits_per_weight,
        );
        dense_refs.push((name.to_string(), li.q_deq.clone()));
        layers.push(layer);
    }
    let model = PackedModel::new(layers);

    // ---- 2. artifact roundtrip -------------------------------------------
    let dir = std::env::temp_dir().join(format!("cloq_serve_demo_{}", std::process::id()));
    let path = dir.join("model.cloqpkd");
    save_artifact(&model, &path)?;
    let loaded = load_artifact(&path)?;
    println!(
        "\n== artifact == saved + reloaded {} layers ({} bytes) from {}",
        loaded.layers.len(),
        std::fs::metadata(&path)?.len(),
        path.display()
    );

    // Parity spot-check: packed fused forward vs the dense q_deq reference.
    let mut max_ulp = 0u64;
    for (name, q_deq) in &dense_refs {
        let layer = loaded.layer(name).expect("layer survived the roundtrip");
        let x = rng.gauss_vec(layer.rows);
        let fused = layer.forward(&x);
        let dense = layer.dense_reference_forward(q_deq, &x);
        for (u, v) in fused.iter().zip(&dense) {
            max_ulp = max_ulp.max(u.to_bits().abs_diff(v.to_bits()));
        }
    }
    println!("   fused-vs-dense max ULP distance across layers: {max_ulp} (contract: 0)");
    anyhow::ensure!(max_ulp == 0, "parity contract violated");

    // ---- 3. serve a concurrent burst -------------------------------------
    let engine = ServeEngine::new(loaded, EngineConfig { workers: 2, max_batch: 16, ..EngineConfig::default() });
    let names: Vec<String> = dense_refs.iter().map(|(n, _)| n.clone()).collect();
    let reqs: Vec<(String, Vec<f64>)> = (0..48)
        .map(|i| {
            let name = &names[i % names.len()];
            let rows = engine_rows(&dense_refs, name);
            (name.clone(), rng.gauss_vec(rows))
        })
        .collect();
    let tickets = engine.submit_all(reqs);
    let mut worst_latency = 0.0f64;
    for t in tickets {
        let resp = t.wait()?;
        worst_latency = worst_latency.max(resp.queue_s + resp.compute_s);
    }
    let stats = engine.shutdown();
    println!(
        "\n== engine == {} requests in {} micro-batches (mean batch {:.1}, max {})",
        stats.requests,
        stats.batches,
        stats.mean_batch(),
        stats.max_batch_seen
    );
    println!(
        "   mean queue wait {:.1} us, worst request latency {:.1} us",
        stats.mean_queue_s() * 1e6,
        worst_latency * 1e6
    );

    std::fs::remove_dir_all(&dir).ok();
    println!("\nserve_demo: OK");
    Ok(())
}

fn engine_rows(refs: &[(String, Matrix)], name: &str) -> usize {
    refs.iter().find(|(n, _)| n == name).map(|(_, q)| q.rows).unwrap()
}

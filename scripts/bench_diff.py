#!/usr/bin/env python3
"""Bench regression gate: compare freshly emitted BENCH_*.json against the
committed baseline copies and fail on large throughput regressions.

Stdlib-only by design (the CI runner and the offline sandbox have no pip).

Usage:
    scripts/bench_diff.py --baseline <dir> --fresh <dir>
                          [--threshold 0.25] [--require-baseline]

The CI bench-smoke job copies the committed BENCH_*.json (if any) into a
baseline directory BEFORE running the benches (which overwrite the files
in the working tree), then calls this script with --require-baseline so a
silently-missing or non-comparable baseline fails loudly instead of
skipping. The committed baselines are SMOKE-MODE records ("smoke": true)
blessed on a CI-class runner via the `bless-baselines` workflow_dispatch
job (.github/workflows/ci.yml).

Gated rows (a >threshold drop in any of them fails the job):
  BENCH_serve.json
    - fused_vs_dense[*].fused.min_s          (fused kernel, per bit width;
                                              lower is better)
    - kernel_batch_sweep[*].requests_per_s_min  (batched kernel throughput)
    - engine.batched.requests_per_s          (the batcher row)
    - engine.serial.requests_per_s
    - submission.interned.requests_per_s     (typed-handle admission — the
                                              interned-id façade row)
    - submission.named.requests_per_s        (legacy stringly admission)
  BENCH_adapters.json
    - adapter_sweep[*].requests_per_s        (multi-tenant engine rows)
    - multi_tenant_throughput_retention      (the multi-tenant headline)
    - mixed_batch.uniform.min_s / .sorted_8_groups.min_s
    - eviction.registers_per_s               (registry churn headline)
  BENCH_forward.json
    - session_sweep[*].pipelined.forwards_per_s  (the pipelined headline)
    - session_sweep[*].serial.forwards_per_s
    - mixed_adapter.forwards_per_s
  BENCH_artifact.json
    - cold_start[*].speedup_v3_vs_v2         (zero-copy headline: mmap v3
                                              vs eager-copy v2 cold start)
    - cold_start[*].v3_open_s                (absolute mapped-open time)
    - replay[*].events_per_s                 (WAL boot-replay rate)
    - group_commit.serial.registers_per_s    (durable register throughput,
                                              1 thread)
    - group_commit.concurrent.registers_per_s  (8 threads sharing fsyncs)
  BENCH_telemetry.json
    - engine.instrumented.requests_per_s     (coalescing burst with full
                                              telemetry)
    - engine.disabled.requests_per_s         (same burst, instruments off)
  BENCH_http.json
    - connections.sweep[*].requests_per_s    (wire throughput per
                                              keep-alive connection count)
    - overhead.http.requests_per_s           (16-connection wire path)
    - overhead.direct.requests_per_s         (the in-process reference)
    - scrape.min_s                           (/metrics round-trip latency)
  BENCH_contention.json
    - single_layer.sweep[*].sharded.requests_per_s   (admission scaling,
                                              1→64 closed-loop submitters)
    - single_layer.sweep[*].global.requests_per_s    (the reference core)
    - single_layer.submitters_64.sharded.requests_per_s  (the scaling
                                              headline, stable path)
    - pipelined.sweep[*].sharded.requests_per_s
    - pipelined.sweep[*].global.requests_per_s
    - pipelined.submitters_64.sharded.requests_per_s
  BENCH_optq.json
    - unblocked.min_s / blocked[*].min_s     (lazy-batch blocking rows)
  BENCH_linalg.json
    - records[*].speedup                     (tiled-vs-naive / root ratios)
  BENCH_generate.json
    - serial.tokens_per_s                    (serial decode cost floor)
    - load.tokens_per_s                      (decoded tokens/s under
                                              Poisson open-loop load)
    - load.ttft_p50_s / .ttft_p95_s / .ttft_p99_s  (admission → first
                                              token latency percentiles)
    - load.itl_p50_s / .itl_p95_s / .itl_p99_s     (inter-token latency
                                              percentiles)

Absolute gates (checked on the FRESH record alone, no baseline involved):
  BENCH_telemetry.json
    - overhead_pct < 5                       (telemetry's design budget:
                                              instruments may not cost the
                                              coalescing hot path 5% of
                                              throughput, ever — not
                                              merely "no worse than last
                                              time")

Absolute floors (fresh record alone, minimum instead of maximum):
  BENCH_contention.json
    - single_layer.submitters_64.speedup_sharded_vs_global >= 1.0
    - pipelined.submitters_64.speedup_sharded_vs_global >= 1.0
                                             (sharded dispatch must never
                                              lose to the global batcher
                                              reference core it replaced,
                                              even on the single-shard
                                              worst-case workload)

Comparisons are skipped (with a note; a FAILURE under --require-baseline)
when:
  - the baseline file does not exist (nothing committed yet);
  - the "smoke" flags of baseline and fresh records differ (full-run
    numbers must never be judged against smoke-mode numbers);
  - the recorded "shape"/"rank"/"layers" identity keys differ (the bench
    was re-sized). NOTE: per-row request counts are NOT identity keys — a
    PR that changes a bench's request count should regenerate the
    committed baseline in the same change.
"""

import argparse
import json
import os
import sys

# (file, dotted path, kind) — kind "time" = lower is better,
# "rate" = higher is better. A '*' path element iterates a list, pairing
# baseline/fresh entries by index.
GATED_ROWS = [
    ("BENCH_serve.json", "fused_vs_dense.*.fused.min_s", "time"),
    ("BENCH_serve.json", "kernel_batch_sweep.*.requests_per_s_min", "rate"),
    ("BENCH_serve.json", "engine.batched.requests_per_s", "rate"),
    ("BENCH_serve.json", "engine.serial.requests_per_s", "rate"),
    ("BENCH_serve.json", "submission.interned.requests_per_s", "rate"),
    ("BENCH_serve.json", "submission.named.requests_per_s", "rate"),
    ("BENCH_adapters.json", "adapter_sweep.*.requests_per_s", "rate"),
    ("BENCH_adapters.json", "multi_tenant_throughput_retention", "rate"),
    ("BENCH_adapters.json", "mixed_batch.uniform.min_s", "time"),
    ("BENCH_adapters.json", "mixed_batch.sorted_8_groups.min_s", "time"),
    ("BENCH_adapters.json", "eviction.registers_per_s", "rate"),
    ("BENCH_forward.json", "session_sweep.*.pipelined.forwards_per_s", "rate"),
    ("BENCH_forward.json", "session_sweep.*.serial.forwards_per_s", "rate"),
    ("BENCH_forward.json", "mixed_adapter.forwards_per_s", "rate"),
    ("BENCH_artifact.json", "cold_start.*.speedup_v3_vs_v2", "rate"),
    ("BENCH_artifact.json", "cold_start.*.v3_open_s", "time"),
    ("BENCH_artifact.json", "replay.*.events_per_s", "rate"),
    ("BENCH_artifact.json", "group_commit.serial.registers_per_s", "rate"),
    ("BENCH_artifact.json", "group_commit.concurrent.registers_per_s", "rate"),
    ("BENCH_telemetry.json", "engine.instrumented.requests_per_s", "rate"),
    ("BENCH_telemetry.json", "engine.disabled.requests_per_s", "rate"),
    ("BENCH_http.json", "connections.sweep.*.requests_per_s", "rate"),
    ("BENCH_http.json", "overhead.http.requests_per_s", "rate"),
    ("BENCH_http.json", "overhead.direct.requests_per_s", "rate"),
    ("BENCH_http.json", "scrape.min_s", "time"),
    ("BENCH_contention.json", "single_layer.sweep.*.sharded.requests_per_s", "rate"),
    ("BENCH_contention.json", "single_layer.sweep.*.global.requests_per_s", "rate"),
    ("BENCH_contention.json", "single_layer.submitters_64.sharded.requests_per_s", "rate"),
    ("BENCH_contention.json", "pipelined.sweep.*.sharded.requests_per_s", "rate"),
    ("BENCH_contention.json", "pipelined.sweep.*.global.requests_per_s", "rate"),
    ("BENCH_contention.json", "pipelined.submitters_64.sharded.requests_per_s", "rate"),
    ("BENCH_optq.json", "unblocked.min_s", "time"),
    ("BENCH_optq.json", "blocked.*.min_s", "time"),
    ("BENCH_linalg.json", "records.*.speedup", "rate"),
    ("BENCH_generate.json", "serial.tokens_per_s", "rate"),
    ("BENCH_generate.json", "load.tokens_per_s", "rate"),
    ("BENCH_generate.json", "load.ttft_p50_s", "time"),
    ("BENCH_generate.json", "load.ttft_p95_s", "time"),
    ("BENCH_generate.json", "load.ttft_p99_s", "time"),
    ("BENCH_generate.json", "load.itl_p50_s", "time"),
    ("BENCH_generate.json", "load.itl_p95_s", "time"),
    ("BENCH_generate.json", "load.itl_p99_s", "time"),
]

# (file, dotted path, max value) — ABSOLUTE ceilings judged on the fresh
# record alone. Unlike GATED_ROWS these are design budgets, not
# regression checks: a baseline that itself violated the budget must not
# grandfather the violation in.
ABS_GATES = [
    ("BENCH_telemetry.json", "overhead_pct", 5.0),
]

# (file, dotted path, min value) — ABSOLUTE floors judged on the fresh
# record alone, the mirror image of ABS_GATES: the value must stay AT OR
# ABOVE the floor. Used for headline speedups that are design guarantees
# rather than regression baselines.
ABS_FLOORS = [
    ("BENCH_contention.json", "single_layer.submitters_64.speedup_sharded_vs_global", 1.0),
    ("BENCH_contention.json", "pipelined.submitters_64.speedup_sharded_vs_global", 1.0),
]

# Records with differing values for any of these keys are not comparable.
# The sweep-size keys (sizes/sessions/adapter_counts/block_sizes) exist
# because '*' rows pair by INDEX: comparing a re-sized sweep positionally
# would silently judge different configurations against each other.
IDENTITY_KEYS = [
    "smoke",
    "shape",
    "rank",
    "layers",
    "sizes",
    "sessions",
    "adapter_counts",
    "block_sizes",
    "event_counts",
    "submitters",
    "workers",
    "connection_counts",
]


def extract(record, path):
    """Yield (pretty_path, value) for a dotted path; '*' fans out lists."""
    parts = path.split(".")

    def walk(node, i, crumbs):
        if i == len(parts):
            yield (".".join(crumbs), node)
            return
        part = parts[i]
        if part == "*":
            if not isinstance(node, list):
                return
            for k, item in enumerate(node):
                yield from walk(item, i + 1, crumbs + [str(k)])
        else:
            if not isinstance(node, dict) or part not in node:
                return
            yield from walk(node[part], i + 1, crumbs + [part])

    yield from walk(record, 0, [])


def comparable(base, fresh, fname):
    for key in IDENTITY_KEYS:
        if base.get(key) != fresh.get(key):
            print(
                f"  SKIP {fname}: '{key}' differs "
                f"(baseline {base.get(key)!r} vs fresh {fresh.get(key)!r}) — "
                "not comparable"
            )
            return False
    return True


def compare_file(fname, base_dir, fresh_dir, threshold, require_baseline):
    """Returns (regressions, compared) for one BENCH file."""
    base_path = os.path.join(base_dir, fname)
    fresh_path = os.path.join(fresh_dir, fname)
    if not os.path.exists(base_path):
        if require_baseline:
            return [f"{fname}: baseline missing (commit a blessed smoke baseline)"], 0
        print(f"  SKIP {fname}: no committed baseline")
        return [], 0
    if not os.path.exists(fresh_path):
        # The bench was supposed to emit this file: that IS a failure.
        return [f"{fname}: fresh copy missing (bench did not emit it)"], 0
    with open(base_path) as f:
        base = json.load(f)
    with open(fresh_path) as f:
        fresh = json.load(f)
    if not comparable(base, fresh, fname):
        if require_baseline:
            return [f"{fname}: baseline not comparable (identity keys differ)"], 0
        return [], 0

    regressions = []
    compared = 0
    for file_pat, path, kind in GATED_ROWS:
        if file_pat != fname:
            continue
        base_rows = dict(extract(base, path))
        fresh_rows = dict(extract(fresh, path))
        for crumb, bval in base_rows.items():
            if not isinstance(bval, (int, float)) or bval <= 0:
                continue
            fval = fresh_rows.get(crumb)
            if not isinstance(fval, (int, float)) or fval <= 0:
                # A gated row the baseline has but the fresh output lost
                # (sweep shrank, field renamed): silent skips here are the
                # exact failure mode --require-baseline exists to prevent.
                if require_baseline:
                    regressions.append(
                        f"{fname}:{crumb} missing or non-positive in fresh output "
                        "(sweep/schema drift? regenerate the baseline)"
                    )
                else:
                    print(f"  SKIP {fname}:{crumb}: no matching fresh row")
                continue
            compared += 1
            if kind == "time":
                ratio = fval / bval  # >1 = slower
                worse = ratio > 1.0 + threshold
                verdict = f"{ratio:.2f}x slower" if ratio > 1 else f"{1 / ratio:.2f}x faster"
            else:
                ratio = fval / bval  # <1 = slower
                worse = ratio < 1.0 - threshold
                verdict = f"{ratio:.2f}x of baseline"
            marker = "REGRESSION" if worse else "ok"
            print(f"  [{marker:>10}] {fname}:{crumb}  {bval:.6g} -> {fval:.6g}  ({verdict})")
            if worse:
                regressions.append(f"{fname}:{crumb} {verdict} (threshold {threshold:.0%})")
    if compared == 0 and require_baseline and not regressions:
        # Both files exist and are comparable, yet no gated row paired up:
        # the schema drifted without regenerating the baseline.
        regressions.append(
            f"{fname}: no gated rows compared (schema drift? regenerate the baseline)"
        )
    return regressions, compared


def check_abs_gates(fresh_dir, require_baseline):
    """Absolute ceilings AND floors on the fresh records; no baseline
    involved. ABS_GATES rows fail at-or-above their budget, ABS_FLOORS
    rows fail strictly below theirs."""
    failures = []
    checked = 0
    gates = [(f, p, v, "ceiling") for f, p, v in ABS_GATES]
    gates += [(f, p, v, "floor") for f, p, v in ABS_FLOORS]
    for fname, path, bound, kind in gates:
        fresh_path = os.path.join(fresh_dir, fname)
        if not os.path.exists(fresh_path):
            # compare_file already flags a missing fresh file when a
            # baseline exists; only flag here when it would otherwise slip
            # through (no committed baseline yet).
            if require_baseline:
                failures.append(f"{fname}: fresh copy missing (abs gate {path} unchecked)")
            else:
                print(f"  SKIP abs {fname}:{path}: no fresh file")
            continue
        with open(fresh_path) as f:
            fresh = json.load(f)
        rows = dict(extract(fresh, path))
        if not rows:
            failures.append(f"{fname}:{path} missing from fresh output (abs gate unchecked)")
            continue
        for crumb, val in rows.items():
            if not isinstance(val, (int, float)):
                failures.append(f"{fname}:{crumb} non-numeric (abs gate unchecked)")
                continue
            checked += 1
            if kind == "ceiling":
                worse = val >= bound
                budget = f"budget < {bound:g}"
                verdict = f"exceeds the absolute budget {bound:g}"
            else:
                worse = val < bound
                budget = f"floor >= {bound:g}"
                verdict = f"falls below the absolute floor {bound:g}"
            marker = "ABS-FAIL" if worse else "ok"
            print(f"  [{marker:>10}] {fname}:{crumb}  {val:.6g}  ({budget})")
            if worse:
                failures.append(f"{fname}:{crumb} = {val:.6g} {verdict}")
    return failures, checked


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", required=True, help="dir holding committed BENCH_*.json")
    ap.add_argument("--fresh", required=True, help="dir holding freshly emitted BENCH_*.json")
    ap.add_argument(
        "--threshold",
        type=float,
        default=0.25,
        help="fractional regression that fails the gate (default 0.25 = 25%%)",
    )
    ap.add_argument(
        "--require-baseline",
        action="store_true",
        help="fail (instead of skipping) when a gated file has no committed or "
        "comparable baseline — the CI bench-smoke mode once baselines exist",
    )
    args = ap.parse_args(argv)

    files = sorted({fname for fname, _, _ in GATED_ROWS})
    all_regressions = []
    total_compared = 0
    print(f"bench_diff: baseline={args.baseline} fresh={args.fresh} threshold={args.threshold:.0%}")
    for fname in files:
        regs, compared = compare_file(
            fname, args.baseline, args.fresh, args.threshold, args.require_baseline
        )
        all_regressions.extend(regs)
        total_compared += compared

    abs_failures, abs_checked = check_abs_gates(args.fresh, args.require_baseline)
    all_regressions.extend(abs_failures)
    total_compared += abs_checked

    if all_regressions:
        print(f"\nbench_diff: {len(all_regressions)} regression(s):")
        for r in all_regressions:
            print(f"  - {r}")
        return 1
    print(f"\nbench_diff: OK ({total_compared} rows compared, none past the threshold)")
    return 0


if __name__ == "__main__":
    sys.exit(main())

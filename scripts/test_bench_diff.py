#!/usr/bin/env python3
"""Self-test for scripts/bench_diff.py (stdlib-only, run by check.sh and
the CI `check` job): synthesizes baseline/fresh BENCH_*.json pairs for
every gated suite and asserts the gate's verdicts — pass on parity and
improvements, fail on regressions past the threshold, skip vs fail
semantics for missing/non-comparable baselines with and without
--require-baseline, schema-drift detection, the ABSOLUTE telemetry
overhead budget, and the ABSOLUTE contention speedup floor (both of
which must fail on the fresh record alone, baseline or no baseline).
"""

import copy
import json
import os
import shutil
import sys
import tempfile

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import bench_diff  # noqa: E402


def synthetic_records():
    """Minimal but schema-faithful records for all ten gated suites."""
    br = {"iters": 10, "mean_s": 1.1e-4, "min_s": 1e-4, "stddev_s": 1e-6}
    return {
        "BENCH_serve.json": {
            "bench": "serve_packed_forward",
            "smoke": True,
            "shape": [96, 96],
            "rank": 16,
            "fused_vs_dense": [
                {"bits": b, "fused": dict(br), "dense_cached": dict(br)} for b in (2, 4, 8)
            ],
            "kernel_batch_sweep": [
                {"batch": b, "requests_per_s_min": 10000.0 * b} for b in (1, 4, 16, 64)
            ],
            "engine": {
                "serial": {"requests_per_s": 5000.0},
                "batched": {"requests_per_s": 9000.0},
            },
            "submission": {
                "interned": {"requests_per_s": 40000.0},
                "named": {"requests_per_s": 33000.0},
            },
        },
        "BENCH_adapters.json": {
            "bench": "serve_adapters",
            "smoke": True,
            "shape": [96, 96],
            "rank": 8,
            "adapter_sweep": [
                {"adapters": a, "requests_per_s": 4000.0} for a in (1, 4, 8)
            ],
            "multi_tenant_throughput_retention": 0.9,
            "mixed_batch": {
                "uniform": dict(br),
                "sorted_8_groups": dict(br, min_s=1.2e-4),
            },
            "eviction": {"registers_per_s": 20000.0},
        },
        "BENCH_forward.json": {
            "bench": "serve_forward_pipeline",
            "smoke": True,
            "shape": [64, 64],
            "layers": 4,
            "rank": 8,
            "sessions": [1, 4, 8],
            "session_sweep": [
                {
                    "sessions": s,
                    "pipelined": {"forwards_per_s": 2000.0 * s},
                    "serial": {"forwards_per_s": 1500.0 * s},
                }
                for s in (1, 4, 8)
            ],
            "mixed_adapter": {"forwards_per_s": 9000.0},
        },
        "BENCH_artifact.json": {
            "bench": "artifact",
            "smoke": True,
            "sizes": [[2, 128], [4, 192]],
            "event_counts": [64],
            "cold_start": [
                {
                    "layers": l,
                    "n": n,
                    "bytes": l * n * n // 2,
                    "v2_open_s": 4e-3 * l,
                    "v3_open_s": 1e-3,
                    "speedup_v3_vs_v2": 4.0 * l,
                }
                for l, n in ((2, 128), (4, 192))
            ],
            "replay": [{"events": 64, "events_per_s": 30000.0}],
            "group_commit": {
                "serial": {"registers": 32, "threads": 1, "registers_per_s": 3000.0},
                "concurrent": {"registers": 32, "threads": 8, "registers_per_s": 8000.0},
                "speedup_concurrent_vs_serial": 2.7,
            },
        },
        "BENCH_telemetry.json": {
            "bench": "telemetry",
            "smoke": True,
            "shape": [96, 96],
            "rank": 16,
            "engine": {
                "instrumented": {"requests": 48, "requests_per_s": 8800.0},
                "disabled": {"requests": 48, "requests_per_s": 9000.0},
            },
            "overhead_pct": 2.2,
        },
        "BENCH_http.json": {
            "bench": "http",
            "smoke": True,
            "shape": [32, 32],
            "connection_counts": [1, 16, 64],
            "connections": {
                "sweep": [
                    {
                        "connections": c,
                        "requests": 192,
                        "requests_per_s": 2000.0 + 100.0 * c,
                    }
                    for c in (1, 16, 64)
                ]
            },
            "overhead": {
                "direct": {"requests": 192, "requests_per_s": 15000.0},
                "http": {"requests": 192, "requests_per_s": 6000.0},
                "wire_overhead_us": 100.0,
            },
            "scrape": dict(br, min_s=3e-4),
        },
        "BENCH_contention.json": {
            "bench": "contention",
            "smoke": True,
            "shape": [48, 48],
            "layers": 4,
            "workers": 4,
            "submitters": [1, 4, 16, 64],
            "single_layer": {
                "sweep": [
                    {
                        "submitters": s,
                        "sharded": {"requests_per_s": 3000.0 + 100.0 * s},
                        "global": {"requests_per_s": 3000.0},
                        "speedup_sharded_vs_global": (3000.0 + 100.0 * s) / 3000.0,
                    }
                    for s in (1, 4, 16, 64)
                ],
                "submitters_64": {
                    "sharded": {"requests_per_s": 9400.0},
                    "global": {"requests_per_s": 3000.0},
                    "speedup_sharded_vs_global": 9400.0 / 3000.0,
                },
            },
            "pipelined": {
                "sweep": [
                    {
                        "submitters": s,
                        "sharded": {"requests_per_s": 1000.0 + 50.0 * s},
                        "global": {"requests_per_s": 1000.0},
                        "speedup_sharded_vs_global": (1000.0 + 50.0 * s) / 1000.0,
                    }
                    for s in (1, 4, 16, 64)
                ],
                "submitters_64": {
                    "sharded": {"requests_per_s": 4200.0},
                    "global": {"requests_per_s": 1000.0},
                    "speedup_sharded_vs_global": 4.2,
                },
            },
        },
        "BENCH_optq.json": {
            "bench": "optq_lazy_batch_blocking",
            "smoke": True,
            "shape": [128, 128],
            "unblocked": dict(br, min_s=2e-2),
            "blocked": [dict(br, min_s=1.4e-2, block_size=bs) for bs in (16, 32)],
        },
        "BENCH_linalg.json": {
            "bench": "linalg_tiled_kernels",
            "smoke": True,
            "sizes": [64, 128, 512, 128, 64],
            "records": [
                {"kernel": "matmul", "n": 64, "speedup": 1.1},
                {"kernel": "matmul", "n": 128, "speedup": 1.4},
                {"kernel": "syrk_t", "shape": [512, 128]},  # no speedup row
                {"kernel": "inv_hessian_root", "n": 64, "speedup": 2.0},
            ],
        },
        "BENCH_generate.json": {
            "bench": "generate",
            "smoke": True,
            "layers": 3,
            "workers": 4,
            "sessions": 8,
            "arrivals": {"process": "poisson", "mean_interarrival_s": 0.002},
            "serial": {"tokens": 150, "wall_s": 0.5, "tokens_per_s": 300.0},
            "load": {
                "total_tokens": 150,
                "wall_s": 0.75,
                "tokens_per_s": 200.0,
                "ttft_p50_s": 0.01,
                "ttft_p95_s": 0.05,
                "ttft_p99_s": 0.1,
                "itl_p50_s": 0.005,
                "itl_p95_s": 0.02,
                "itl_p99_s": 0.05,
                "itl_gaps": 142,
                "mean_batch": 2.5,
            },
        },
    }


def write_dir(d, records):
    os.makedirs(d, exist_ok=True)
    for fname, rec in records.items():
        with open(os.path.join(d, fname), "w") as f:
            json.dump(rec, f)


def run(base, fresh, *extra):
    return bench_diff.main(["--baseline", base, "--fresh", fresh, *extra])


def main():
    tmp = tempfile.mkdtemp(prefix="bench_diff_selftest_")
    failures = []

    def check(name, got, want):
        marker = "ok" if got == want else "FAIL"
        print(f"[{marker}] {name}: exit {got} (want {want})")
        if got != want:
            failures.append(name)

    try:
        base = os.path.join(tmp, "base")
        fresh = os.path.join(tmp, "fresh")
        write_dir(base, synthetic_records())

        # 1. Identical numbers pass, with and without --require-baseline.
        write_dir(fresh, synthetic_records())
        check("identical", run(base, fresh), 0)
        check("identical --require-baseline", run(base, fresh, "--require-baseline"), 0)

        # 2. Improvements pass (rates up, times down).
        recs = synthetic_records()
        recs["BENCH_forward.json"]["session_sweep"][2]["pipelined"]["forwards_per_s"] *= 3.0
        recs["BENCH_serve.json"]["fused_vs_dense"][1]["fused"]["min_s"] /= 3.0
        write_dir(fresh, recs)
        check("improvement", run(base, fresh), 0)

        # 3. A >25% rate drop in the new forward headline fails.
        recs = synthetic_records()
        recs["BENCH_forward.json"]["session_sweep"][2]["pipelined"]["forwards_per_s"] *= 0.5
        write_dir(fresh, recs)
        check("forward rate regression", run(base, fresh), 1)

        # 3a. The interned-admission headline is gated: a >25% drop fails.
        recs = synthetic_records()
        recs["BENCH_serve.json"]["submission"]["interned"]["requests_per_s"] *= 0.6
        write_dir(fresh, recs)
        check("submission-overhead regression", run(base, fresh), 1)

        # 4. A >25% slowdown in a gated time row fails (adapters headline).
        recs = synthetic_records()
        recs["BENCH_adapters.json"]["mixed_batch"]["uniform"]["min_s"] *= 1.5
        write_dir(fresh, recs)
        check("adapters time regression", run(base, fresh), 1)

        # 5. The retention headline is gated too.
        recs = synthetic_records()
        recs["BENCH_adapters.json"]["multi_tenant_throughput_retention"] = 0.5
        write_dir(fresh, recs)
        check("retention regression", run(base, fresh), 1)

        # 5a. The zero-copy cold-start headline is gated: a >25% drop in
        # the v3-vs-v2 speedup fails, as does a slower absolute mapped open.
        recs = synthetic_records()
        recs["BENCH_artifact.json"]["cold_start"][1]["speedup_v3_vs_v2"] *= 0.5
        write_dir(fresh, recs)
        check("cold-start speedup regression", run(base, fresh), 1)
        recs = synthetic_records()
        recs["BENCH_artifact.json"]["cold_start"][0]["v3_open_s"] *= 2.0
        write_dir(fresh, recs)
        check("mapped-open time regression", run(base, fresh), 1)

        # 5b. The WAL replay rate is gated too.
        recs = synthetic_records()
        recs["BENCH_artifact.json"]["replay"][0]["events_per_s"] *= 0.5
        write_dir(fresh, recs)
        check("wal replay regression", run(base, fresh), 1)

        # 5b'. So is the group-commit register throughput (both modes).
        recs = synthetic_records()
        recs["BENCH_artifact.json"]["group_commit"]["concurrent"]["registers_per_s"] *= 0.5
        write_dir(fresh, recs)
        check("group-commit rate regression", run(base, fresh), 1)

        # 5d. The telemetry throughput rows are relative-gated like any
        # other rate...
        recs = synthetic_records()
        recs["BENCH_telemetry.json"]["engine"]["instrumented"]["requests_per_s"] *= 0.5
        write_dir(fresh, recs)
        check("telemetry throughput regression", run(base, fresh), 1)

        # 5e. ...but overhead_pct is an ABSOLUTE budget: >= 5 fails even
        # when the baseline carries the identical (bad) number — no
        # grandfathering a violation in.
        recs = synthetic_records()
        recs["BENCH_telemetry.json"]["overhead_pct"] = 6.0
        bad_base = os.path.join(tmp, "bad_overhead_base")
        write_dir(bad_base, copy.deepcopy(recs))
        write_dir(fresh, recs)
        check("telemetry overhead over budget", run(bad_base, fresh), 1)

        # 5f. A negative overhead (noise favored the instrumented run) is
        # within budget.
        recs = synthetic_records()
        recs["BENCH_telemetry.json"]["overhead_pct"] = -1.3
        write_dir(fresh, recs)
        check("telemetry negative overhead passes", run(base, fresh), 0)

        # 5g. Losing the overhead_pct row entirely fails — an unchecked
        # absolute gate is a failure, not a skip, even without
        # --require-baseline.
        recs = synthetic_records()
        del recs["BENCH_telemetry.json"]["overhead_pct"]
        write_dir(fresh, recs)
        check("telemetry overhead row missing", run(base, fresh), 1)

        # 5l. The HTTP wire rows are gated: a >25% drop in a per-connection
        # throughput row or in the 16-connection overhead row fails, as
        # does a slower /metrics scrape.
        recs = synthetic_records()
        recs["BENCH_http.json"]["connections"]["sweep"][2]["requests_per_s"] *= 0.5
        write_dir(fresh, recs)
        check("http connection-sweep regression", run(base, fresh), 1)
        recs = synthetic_records()
        recs["BENCH_http.json"]["overhead"]["http"]["requests_per_s"] *= 0.6
        write_dir(fresh, recs)
        check("http wire-overhead regression", run(base, fresh), 1)
        recs = synthetic_records()
        recs["BENCH_http.json"]["scrape"]["min_s"] *= 2.0
        write_dir(fresh, recs)
        check("http scrape latency regression", run(base, fresh), 1)

        # 5m. A re-sized connection sweep ('connection_counts' identity
        # key) is not comparable: skip by default, fail under the flag.
        recs = synthetic_records()
        recs["BENCH_http.json"]["connection_counts"] = [1, 8]
        recs["BENCH_http.json"]["connections"]["sweep"] = recs["BENCH_http.json"][
            "connections"
        ]["sweep"][:2]
        write_dir(fresh, recs)
        check("re-sized connection_counts skips", run(base, fresh), 0)
        check(
            "re-sized connection_counts fails under --require-baseline",
            run(base, fresh, "--require-baseline"),
            1,
        )

        # 5n. The generation latency percentiles are gated time rows: a
        # >25% TTFT or ITL blow-up fails.
        recs = synthetic_records()
        recs["BENCH_generate.json"]["load"]["ttft_p99_s"] *= 2.0
        write_dir(fresh, recs)
        check("generate ttft regression", run(base, fresh), 1)
        recs = synthetic_records()
        recs["BENCH_generate.json"]["load"]["itl_p95_s"] *= 1.5
        write_dir(fresh, recs)
        check("generate itl regression", run(base, fresh), 1)

        # 5o. The decoded-tokens/s rows are gated rates: a >25% drop in
        # either the serial floor or the under-load aggregate fails.
        recs = synthetic_records()
        recs["BENCH_generate.json"]["load"]["tokens_per_s"] *= 0.5
        write_dir(fresh, recs)
        check("generate load throughput regression", run(base, fresh), 1)
        recs = synthetic_records()
        recs["BENCH_generate.json"]["serial"]["tokens_per_s"] *= 0.6
        write_dir(fresh, recs)
        check("generate serial throughput regression", run(base, fresh), 1)

        # 5p. Latency jitter inside the threshold passes — open-loop
        # percentiles are noisy by construction and the gate must only
        # catch collapses.
        recs = synthetic_records()
        recs["BENCH_generate.json"]["load"]["ttft_p95_s"] *= 1.2
        recs["BENCH_generate.json"]["load"]["itl_p99_s"] *= 0.8
        write_dir(fresh, recs)
        check("generate jitter within threshold", run(base, fresh), 0)

        # 5q. A re-sized session count ('sessions' identity key) is not
        # comparable: skip by default, fail under --require-baseline.
        recs = synthetic_records()
        recs["BENCH_generate.json"]["sessions"] = 16
        write_dir(fresh, recs)
        check("re-sized generate sessions skips", run(base, fresh), 0)
        check(
            "re-sized generate sessions fails under --require-baseline",
            run(base, fresh, "--require-baseline"),
            1,
        )

        # 5h. The contention scaling rows are relative-gated: a >25% drop
        # in the 64-submitter sharded headline fails, as does one inside
        # the sweep.
        recs = synthetic_records()
        recs["BENCH_contention.json"]["single_layer"]["submitters_64"]["sharded"][
            "requests_per_s"
        ] *= 0.5
        write_dir(fresh, recs)
        check("contention headline regression", run(base, fresh), 1)
        recs = synthetic_records()
        recs["BENCH_contention.json"]["pipelined"]["sweep"][1]["global"][
            "requests_per_s"
        ] *= 0.5
        write_dir(fresh, recs)
        check("contention sweep regression", run(base, fresh), 1)

        # 5i. The sharded-vs-global speedup is an ABSOLUTE floor: < 1.0 at
        # 64 submitters fails even when the baseline carries the identical
        # (bad) number — the sharded core must never lose to the global
        # reference core, no grandfathering.
        recs = synthetic_records()
        recs["BENCH_contention.json"]["pipelined"]["submitters_64"][
            "speedup_sharded_vs_global"
        ] = 0.93
        bad_base = os.path.join(tmp, "bad_speedup_base")
        write_dir(bad_base, copy.deepcopy(recs))
        write_dir(fresh, recs)
        check("contention speedup under floor", run(bad_base, fresh), 1)

        # 5j. Exactly 1.0 sits ON the floor and passes (ties are allowed;
        # only losing to the reference core fails).
        recs = synthetic_records()
        for w in ("single_layer", "pipelined"):
            recs["BENCH_contention.json"][w]["submitters_64"][
                "speedup_sharded_vs_global"
            ] = 1.0
        write_dir(fresh, recs)
        check("contention speedup on the floor passes", run(base, fresh), 0)

        # 5k. Losing a floored row entirely fails — an unchecked absolute
        # floor is a failure, not a skip, even without --require-baseline.
        recs = synthetic_records()
        del recs["BENCH_contention.json"]["single_layer"]["submitters_64"][
            "speedup_sharded_vs_global"
        ]
        write_dir(fresh, recs)
        check("contention speedup row missing", run(base, fresh), 1)

        # 5c. A re-sized replay sweep ('event_counts' identity key) is not
        # comparable: skip by default, fail under --require-baseline.
        recs = synthetic_records()
        recs["BENCH_artifact.json"]["event_counts"] = [64, 256]
        recs["BENCH_artifact.json"]["replay"].append(
            {"events": 256, "events_per_s": 28000.0}
        )
        write_dir(fresh, recs)
        check("re-sized event_counts skips", run(base, fresh), 0)
        check(
            "re-sized event_counts fails under --require-baseline",
            run(base, fresh, "--require-baseline"),
            1,
        )

        # 6. Within-threshold drift passes.
        recs = synthetic_records()
        recs["BENCH_optq.json"]["unblocked"]["min_s"] *= 1.2
        recs["BENCH_linalg.json"]["records"][0]["speedup"] *= 0.85
        write_dir(fresh, recs)
        check("within threshold", run(base, fresh), 0)

        # 7. Missing baseline: skip by default, fail under --require-baseline.
        partial = os.path.join(tmp, "partial_base")
        recs = synthetic_records()
        del recs["BENCH_forward.json"]
        write_dir(partial, recs)
        write_dir(fresh, synthetic_records())
        check("missing baseline skips", run(partial, fresh), 0)
        check(
            "missing baseline fails loudly",
            run(partial, fresh, "--require-baseline"),
            1,
        )

        # 8. Smoke-flag mismatch: skip by default, fail under the flag.
        full_base = os.path.join(tmp, "full_base")
        recs = copy.deepcopy(synthetic_records())
        for rec in recs.values():
            rec["smoke"] = False
        write_dir(full_base, recs)
        write_dir(fresh, synthetic_records())
        check("smoke mismatch skips", run(full_base, fresh), 0)
        check("smoke mismatch fails loudly", run(full_base, fresh, "--require-baseline"), 1)

        # 9. A fresh file the bench failed to emit is always a failure.
        write_dir(fresh, synthetic_records())
        os.remove(os.path.join(fresh, "BENCH_serve.json"))
        check("fresh missing", run(base, fresh), 1)

        # 9a. A RE-SIZED sweep is not comparable, even when row counts
        # still line up positionally: the sweep-size identity key differs
        # — skip by default, fail under --require-baseline.
        recs = synthetic_records()
        recs["BENCH_linalg.json"]["sizes"] = [96, 192]
        write_dir(fresh, recs)
        check("re-sized sweep skips", run(base, fresh), 0)
        check(
            "re-sized sweep fails under --require-baseline",
            run(base, fresh, "--require-baseline"),
            1,
        )

        # 9b. PARTIAL sweep drift: the baseline's 8-session headline row
        # vanishes from the fresh output while earlier rows still pair up
        # — skip by default, fail under --require-baseline.
        recs = synthetic_records()
        recs["BENCH_forward.json"]["session_sweep"] = recs["BENCH_forward.json"][
            "session_sweep"
        ][:2]
        write_dir(fresh, recs)
        check("partial sweep drift skips", run(base, fresh), 0)
        check(
            "partial sweep drift fails under --require-baseline",
            run(base, fresh, "--require-baseline"),
            1,
        )

        # 10. Schema drift (gated paths vanish) is caught under the flag.
        recs = synthetic_records()
        recs["BENCH_forward.json"]["session_sweep"] = []
        del recs["BENCH_forward.json"]["mixed_adapter"]
        drift_base = os.path.join(tmp, "drift_base")
        drift = synthetic_records()
        drift["BENCH_forward.json"]["session_sweep"] = []
        del drift["BENCH_forward.json"]["mixed_adapter"]
        write_dir(drift_base, drift)
        write_dir(fresh, recs)
        check("schema drift skips by default", run(drift_base, fresh), 0)
        check(
            "schema drift fails under --require-baseline",
            run(drift_base, fresh, "--require-baseline"),
            1,
        )
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    if failures:
        print(f"\ntest_bench_diff: {len(failures)} failure(s): {failures}")
        return 1
    print("\ntest_bench_diff: all cases passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())

#!/usr/bin/env bash
# Repo gate: build + tests + clippy on the Rust workspace.
#
# Usage: scripts/check.sh [--bench]
#   --bench  additionally run the perf benches that emit BENCH_*.json
#            (bench_optq / bench_linalg / bench_serve; slow — not part of
#            the default gate)
#
# The crates.io-free sandbox is the default environment: all dependencies
# are vendored path crates, so everything below runs with --offline.

set -euo pipefail
cd "$(dirname "$0")/.."

CARGO_FLAGS=(--offline)

echo "== cargo build --release =="
cargo build --release "${CARGO_FLAGS[@]}"

echo "== cargo test -q =="
cargo test -q "${CARGO_FLAGS[@]}"

# Clippy gate on the main crate (vendored shims excluded): deny warnings on
# the modules this repo owns. Tolerated to be absent (minimal toolchains).
if cargo clippy --version >/dev/null 2>&1; then
    echo "== cargo clippy -p cloq (deny warnings) =="
    cargo clippy -p cloq --all-targets "${CARGO_FLAGS[@]}" -- -D warnings
else
    echo "== clippy not installed; skipping lint gate =="
fi

# rustfmt gate (tolerated-absent like clippy). Advisory for now: the
# pre-gate tree was written before the formatter was wired in, so style
# drift reports loudly but does not fail the gate — tightening to a hard
# failure once the tree is formatted is tracked in ROADMAP.md Open items.
if cargo fmt --version >/dev/null 2>&1; then
    echo "== cargo fmt --check (advisory) =="
    if ! cargo fmt --check; then
        echo "WARNING: rustfmt reports style drift (advisory — not failing the gate)"
    fi
else
    echo "== rustfmt not installed; skipping format gate =="
fi

if [[ "${1:-}" == "--bench" ]]; then
    echo "== perf benches (BENCH_optq.json / BENCH_linalg.json / BENCH_serve.json) =="
    cargo bench --bench bench_optq "${CARGO_FLAGS[@]}"
    cargo bench --bench bench_linalg "${CARGO_FLAGS[@]}"
    cargo bench --bench bench_serve "${CARGO_FLAGS[@]}"
fi

echo "check.sh: all green"

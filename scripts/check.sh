#!/usr/bin/env bash
# Repo gate: build + tests + clippy + rustfmt on the Rust workspace.
#
# Usage: scripts/check.sh [--bench]
#   --bench  additionally run the perf benches that emit BENCH_*.json
#            (bench_optq / bench_linalg / bench_serve / bench_adapters /
#            bench_forward / bench_artifact / bench_telemetry /
#            bench_contention / bench_http / bench_generate; slow — not
#            part of the default gate). Set
#            CLOQ_BENCH_SMOKE=1 for the small-size smoke mode the CI
#            bench-smoke job uses (seconds instead of minutes; records
#            carry "smoke": true so scripts/bench_diff.py never mixes
#            smoke and full baselines).
#
# CI (.github/workflows/ci.yml) runs this twice:
#   * job `check`       — scripts/check.sh            (the hard gate)
#   * job `bench-smoke` — CLOQ_BENCH_SMOKE=1 scripts/check.sh --bench,
#                         then scripts/bench_diff.py --require-baseline
#                         against the committed smoke-mode BENCH_*.json
#                         baselines (>25% throughput regression on the
#                         gated rows fails the job; so does a silently
#                         missing baseline), and uploads the fresh JSON
#                         as a workflow artifact so the perf trajectory
#                         is recorded per PR. The `bless-baselines`
#                         workflow_dispatch job regenerates the committed
#                         baselines on a CI-class runner.
#
# The crates.io-free sandbox is the default environment: all dependencies
# are vendored path crates, so everything below runs with --offline.

set -euo pipefail
cd "$(dirname "$0")/.."

CARGO_FLAGS=(--offline)

echo "== cargo build --release =="
cargo build --release "${CARGO_FLAGS[@]}"

echo "== cargo test -q =="
cargo test -q "${CARGO_FLAGS[@]}"

# Durability gate — explicit so a filtered or partial test run can never
# silently drop it: the deterministic fault-injection recovery suite
# (truncation at every byte offset + bit-identical post-recovery forwards)
# must pass in the default gate, not just under --bench.
echo "== cargo test -q --test crash_wal (fault-injection recovery suite) =="
cargo test -q --test crash_wal "${CARGO_FLAGS[@]}"

# Wire-contract gate — explicit for the same reason: the HTTP loopback
# suite (0-ULP wire parity, the {code, status} error contract, the
# auth/quota taxonomy, torn-input robustness, chunked streaming, and the
# push-parser mutation fuzz) is the only thing standing between the typed
# façade and every non-Rust consumer.
echo "== cargo test -q --test http_serve (HTTP wire-contract suite) =="
cargo test -q --test http_serve "${CARGO_FLAGS[@]}"

# Decode-parity gate — explicit for the same reason: token-level
# generation through the pipelined batcher must stay bit-identical (0 ULP)
# to the serial reference across methods, bit widths, adapters, hot-swaps,
# and concurrent sessions, with seeded sampling exactly reproducible.
echo "== cargo test -q --test parity_generate (token-level decode parity suite) =="
cargo test -q --test parity_generate "${CARGO_FLAGS[@]}"

# Clippy gate — HARD and WORKSPACE-WIDE: deny warnings on every target of
# every member crate (lib, bins, examples, benches, tests, and the
# vendored shims — the whole tree is lint-clean). Tolerated to be absent
# (minimal toolchains); CI always installs the component.
if cargo clippy --version >/dev/null 2>&1; then
    echo "== cargo clippy --workspace --all-targets (deny warnings) =="
    cargo clippy --workspace --all-targets "${CARGO_FLAGS[@]}" -- -D warnings
else
    echo "== clippy not installed; skipping lint gate =="
fi

# rustfmt gate — HARD: style drift fails the run (the tree is formatted;
# the advisory grace period is over). Tolerated-absent like clippy for
# minimal toolchains; CI always installs the component.
if cargo fmt --version >/dev/null 2>&1; then
    echo "== cargo fmt --check (hard gate) =="
    cargo fmt --check
else
    echo "== rustfmt not installed; skipping format gate =="
fi

# bench_diff gate self-test (stdlib-only python; tolerated-absent for
# toolchain-only sandboxes, CI runners always have python3).
if command -v python3 >/dev/null 2>&1; then
    echo "== scripts/test_bench_diff.py =="
    python3 scripts/test_bench_diff.py
else
    echo "== python3 not installed; skipping bench_diff self-test =="
fi

if [[ "${1:-}" == "--bench" ]]; then
    echo "== perf benches (BENCH_{optq,linalg,serve,adapters,forward,artifact,telemetry,contention,http,generate}.json) =="
    cargo bench --bench bench_optq "${CARGO_FLAGS[@]}"
    cargo bench --bench bench_linalg "${CARGO_FLAGS[@]}"
    cargo bench --bench bench_serve "${CARGO_FLAGS[@]}"
    cargo bench --bench bench_adapters "${CARGO_FLAGS[@]}"
    cargo bench --bench bench_forward "${CARGO_FLAGS[@]}"
    cargo bench --bench bench_artifact "${CARGO_FLAGS[@]}"
    cargo bench --bench bench_telemetry "${CARGO_FLAGS[@]}"
    cargo bench --bench bench_contention "${CARGO_FLAGS[@]}"
    cargo bench --bench bench_http "${CARGO_FLAGS[@]}"
    cargo bench --bench bench_generate "${CARGO_FLAGS[@]}"
fi

echo "check.sh: all green"

"""AOT lowering: TinyGPT entry points → HLO *text* + manifest.json.

HLO text (NOT `.serialize()`) is the interchange format: jax ≥ 0.5 emits
HloModuleProtos with 64-bit instruction ids which the `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:
    python -m compile.aot --config tiny-s --out-dir ../artifacts
    python -m compile.aot --config micro --entries eval_loss,lora_step

Artifacts land in `<out-dir>/<config>/<entry>.hlo.txt` plus one
`<out-dir>/<config>/manifest.json` describing the exact flat input/output
ordering each graph expects (consumed by rust/src/model/manifest.rs).
Python runs ONCE at build time; the rust binary is self-contained after.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile.model import PRESETS, build_entrypoints, config_manifest

jax.config.update("jax_platform_name", "cpu")

_DTYPES = {"f32": jnp.float32, "i32": jnp.int32}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(fn, input_specs) -> str:
    args = [
        jax.ShapeDtypeStruct(tuple(s["shape"]), _DTYPES[s["dtype"]])
        for s in input_specs
    ]
    return to_hlo_text(jax.jit(fn).lower(*args))


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--config", default="tiny-s", choices=sorted(PRESETS))
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--entries", default="",
                   help="comma-separated subset (default: all)")
    p.add_argument("--seq", type=int, default=0,
                   help="override sequence length; artifacts land under "
                        "<config>-seq<N> (Table 9 sweep)")
    args = p.parse_args()

    cfg = PRESETS[args.config]
    if args.seq:
        from dataclasses import replace
        cfg = replace(cfg, seq=args.seq, name=f"{cfg.name}-seq{args.seq}")
    out_dir = os.path.join(args.out_dir, cfg.name)
    os.makedirs(out_dir, exist_ok=True)

    entries = build_entrypoints(cfg)
    wanted = set(args.entries.split(",")) if args.entries else set(entries)

    manifest = {"config": config_manifest(cfg), "entrypoints": {}}
    for name, (fn, ins, outs) in entries.items():
        if name not in wanted:
            continue
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        text = lower_entry(fn, ins)
        with open(path, "w") as f:
            f.write(text)
        manifest["entrypoints"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": ins,
            "outputs": outs,
        }
        print(f"  {name}: {len(ins)} inputs, {len(outs)} outputs, "
              f"{len(text) / 1e6:.2f} MB HLO")

    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()

"""Pure-jnp oracles for the Pallas kernels (the CORE correctness signal).

Everything here is deliberately written in the most transparent jnp form;
`python/tests/test_kernel.py` asserts the Pallas kernels match these to
float32 tolerance across shape/dtype/group-size sweeps (hypothesis), and
`rust/tests/golden_quant.rs` cross-checks the Rust quantizers against
golden files generated from these functions.
"""

from __future__ import annotations

import jax.numpy as jnp


def dequant_ref(codes, scales, zeros, group_size: int):
    """Group-wise asymmetric INT dequantization.

    codes:  [K, N] int32 quantization codes
    scales: [G, N] f32 per-(group, out-channel) scales, G = ceil(K/gs)
    zeros:  [G, N] f32 zero points
    returns [K, N] f32 dequantized weights: (code - zero) * scale
    """
    k = codes.shape[0]
    row_group = jnp.arange(k) // group_size  # [K]
    s = scales[row_group]  # [K, N]
    z = zeros[row_group]  # [K, N]
    return (codes.astype(jnp.float32) - z) * s


def qlora_matmul_ref(x, codes, scales, zeros, a, b, group_size: int):
    """y = x · deq(codes) + (x · A) · Bᵀ  — the fused serving hot-spot.

    x: [M, K] f32; codes: [K, N]; a: [K, r]; b: [N, r].
    """
    w = dequant_ref(codes, scales, zeros, group_size)
    base = x @ w
    lora = (x @ a) @ b.T
    return base + lora


def gram_ref(x):
    """H = XᵀX for calibration. x: [S, F] → [F, F]."""
    return x.T @ x


def quantize_rtn_ref(w, bits: int, group_size: int):
    """Asymmetric uniform INT quantizer (mirrors rust/src/quant/grid.rs).

    w: [K, N] f32. Returns (codes i32 [K,N], scales f32 [G,N], zeros f32 [G,N]).
    Groups run along the K (input-feature) axis — same orientation as Rust.
    """
    k, n = w.shape
    g = -(-k // group_size)
    qmax = 2**bits - 1
    pad = g * group_size - k
    wp = jnp.pad(w, ((0, pad), (0, 0)))
    wg = wp.reshape(g, group_size, n)
    if pad > 0:
        # Padded rows must not affect group stats.
        valid = jnp.arange(g * group_size).reshape(g, group_size, 1) < k
        lo = jnp.min(jnp.where(valid, wg, jnp.inf), axis=1)
        hi = jnp.max(jnp.where(valid, wg, -jnp.inf), axis=1)
    else:
        lo = wg.min(axis=1)
        hi = wg.max(axis=1)
    # Grid must contain 0 (matches Rust find_params).
    lo = jnp.minimum(lo, 0.0)
    hi = jnp.maximum(hi, 0.0)
    scale = (hi - lo) / qmax
    scale = jnp.where(scale <= 0, 1.0, scale)
    zero = jnp.round(-lo / scale)
    row_group = jnp.arange(k) // group_size
    s_full = scale[row_group]
    z_full = zero[row_group]
    codes = jnp.clip(jnp.round(w / s_full + z_full), 0, qmax).astype(jnp.int32)
    return codes, scale.astype(jnp.float32), zero.astype(jnp.float32)

"""L1 Pallas kernel: fused group-dequantize matmul with LoRA correction.

The paper's serving-time hot spot is `y = X·(Q + A·Bᵀ)` where `Q` lives in
`b`-bit codes + per-group scales/zeros, and `A, B` are the fp LoRA factors.
The CUDA implementations the paper builds on (GPTQ / bitsandbytes kernels)
dequantize warp-tiles into shared memory and feed tensor cores; the TPU
re-expression here (DESIGN.md §Hardware-Adaptation):

* BlockSpec tiles the output grid (M/bm, N/bn); each program stages an
  (bm × K) x-tile and a (K × bn) code-tile HBM→VMEM.
* Dequantization `(code − zero) · scale` is a VPU elementwise op on the
  VMEM-resident tile (the analogue of warp-level dequant into smem).
* Both the dense product and the two skinny LoRA products run on the MXU
  (`jnp.dot` with f32 accumulation; bf16-ready).
* The K dimension stays resident (layer widths here are ≤1k, so a full
  K-panel fits VMEM comfortably; see the VMEM budget in DESIGN.md §Perf).

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered to plain HLO ops — numerics are
identical, TPU performance is estimated analytically.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _qlora_kernel(x_ref, codes_ref, scales_ref, zeros_ref, a_ref, b_ref, o_ref,
                  *, group_size: int):
    """One (bm, bn) output tile.

    x_ref:      [bm, K]   f32
    codes_ref:  [K, bn]   i32
    scales_ref: [G, bn]   f32
    zeros_ref:  [G, bn]   f32
    a_ref:      [K, r]    f32
    b_ref:      [bn, r]   f32
    o_ref:      [bm, bn]  f32
    """
    x = x_ref[...]
    codes = codes_ref[...]
    k = codes.shape[0]
    # VPU dequant: expand per-group params to per-row (static shapes).
    row_group = jnp.arange(k) // group_size
    s = scales_ref[...][row_group]  # [K, bn]
    z = zeros_ref[...][row_group]
    w = (codes.astype(jnp.float32) - z) * s
    # MXU: dense base product, f32 accumulation.
    acc = jnp.dot(x, w, preferred_element_type=jnp.float32)
    # MXU: skinny LoRA correction on the same x tile.
    xa = jnp.dot(x, a_ref[...], preferred_element_type=jnp.float32)
    acc += jnp.dot(xa, b_ref[...].T, preferred_element_type=jnp.float32)
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("group_size", "block_m", "block_n"))
def qlora_matmul(x, codes, scales, zeros, a, b, *, group_size: int = 64,
                 block_m: int = 64, block_n: int = 128):
    """Fused `x @ dequant(codes, scales, zeros) + (x @ a) @ b.T`.

    x: [M, K] f32; codes: [K, N] i32; scales/zeros: [G, N] f32 with
    G = ceil(K / group_size); a: [K, r] f32; b: [N, r] f32 → [M, N] f32.
    """
    m, k = x.shape
    k2, n = codes.shape
    assert k == k2, (k, k2)
    g = scales.shape[0]
    assert g == -(-k // group_size), (g, k, group_size)
    r = a.shape[1]
    assert a.shape == (k, r) and b.shape == (n, r)

    bm = min(block_m, m)
    bn = min(block_n, n)
    # Pallas needs the grid to tile the arrays exactly; pad M/N up.
    mp = -(-m // bm) * bm
    np_ = -(-n // bn) * bn
    x_p = jnp.pad(x, ((0, mp - m), (0, 0)))
    codes_p = jnp.pad(codes, ((0, 0), (0, np_ - n)))
    scales_p = jnp.pad(scales, ((0, 0), (0, np_ - n)), constant_values=1.0)
    zeros_p = jnp.pad(zeros, ((0, 0), (0, np_ - n)))
    b_p = jnp.pad(b, ((0, np_ - n), (0, 0)))

    grid = (mp // bm, np_ // bn)
    out = pl.pallas_call(
        functools.partial(_qlora_kernel, group_size=group_size),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),       # x panel
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),       # codes panel
            pl.BlockSpec((g, bn), lambda i, j: (0, j)),       # scales
            pl.BlockSpec((g, bn), lambda i, j: (0, j)),       # zeros
            pl.BlockSpec((k, r), lambda i, j: (0, 0)),        # A (shared)
            pl.BlockSpec((bn, r), lambda i, j: (j, 0)),       # B panel
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,  # CPU sandbox; see module docstring
    )(x_p, codes_p, scales_p, zeros_p, a, b_p)
    return out[:m, :n]


def _gram_kernel(x_ref, o_ref):
    """Accumulate H += X_tileᵀ · X_tile over the sample-block grid."""
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    o_ref[...] += jnp.dot(x.T, x, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_s",))
def gram(x, *, block_s: int = 128):
    """H = XᵀX via a Pallas tiled accumulation. x: [S, F] → [F, F]."""
    s, f = x.shape
    bs = min(block_s, s)
    sp = -(-s // bs) * bs
    x_p = jnp.pad(x, ((0, sp - s), (0, 0)))  # zero rows don't affect XᵀX
    return pl.pallas_call(
        _gram_kernel,
        grid=(sp // bs,),
        in_specs=[pl.BlockSpec((bs, f), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((f, f), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((f, f), jnp.float32),
        interpret=True,
    )(x_p)

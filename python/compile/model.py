"""L2: TinyGPT — the transformer compute graphs AOT-lowered to HLO.

A pre-LN, decoder-only transformer with LoRA adapters on every linear map
(q/k/v/o/up/down), standing in for Llama2/Llama3/Mistral (DESIGN.md §3
substitution table). Six entry points are lowered by `aot.py`:

=================  ==========================================================
``pretrain_step``  AdamW step on ALL parameters (builds the "pre-trained"
                   model the paper starts from).
``lora_step``      AdamW step on LoRA parameters only; base weights are
                   frozen inputs (the paper's fine-tuning stage).
``eval_loss``      (masked loss sum, token count) for perplexity.
``eval_logits``    full logits for greedy decode / choice scoring.
``capture_grams``  per-layer activation Gram matrices H = XᵀX for
                   calibration (uses the L1 Pallas ``gram`` kernel).
``qeval_loss``     the quantized serving path: base weights arrive as INT
                   codes + scales/zeros and every linear runs through the
                   L1 Pallas ``qlora_matmul`` kernel.
=================  ==========================================================

All entry points are pure functions over a *flat ordered argument list*;
the ordering contract is exported to `artifacts/manifest.json` and consumed
by `rust/src/model/manifest.rs`. Python never runs at serve time.
"""

from __future__ import annotations

from dataclasses import dataclass, field, asdict

import jax
import jax.numpy as jnp

from compile.kernels.qlora_matmul import gram, qlora_matmul


@dataclass
class Config:
    """Model + lowering configuration (mirrored in rust/src/model/config.rs)."""

    name: str = "tiny-s"
    vocab: int = 260  # 256 bytes + pad/bos/eos/sep
    d_model: int = 96
    n_layers: int = 2
    n_heads: int = 4
    d_ff: int = 256
    seq: int = 64
    batch: int = 8
    rank: int = 16
    group_size: int = 64  # quantization group size for the qeval path

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


# Preset model families standing in for the paper's four architectures.
PRESETS = {
    # Llama2-7B stand-in (the "small" model of Tables 1/3/5…)
    "tiny-s": Config(name="tiny-s", d_model=96, n_layers=2, n_heads=4, d_ff=256),
    # Llama2-13B stand-in (deeper + wider)
    "tiny-m": Config(name="tiny-m", d_model=128, n_layers=3, n_heads=4, d_ff=384),
    # Llama3-8B stand-in (wide FFN ratio, more heads)
    "tiny-wide": Config(name="tiny-wide", d_model=128, n_layers=2, n_heads=8, d_ff=512),
    # Mistral-7B stand-in (deep + narrow)
    "tiny-deep": Config(name="tiny-deep", d_model=96, n_layers=4, n_heads=4, d_ff=256),
    # Micro config for fast integration tests
    "micro": Config(name="micro", d_model=32, n_layers=1, n_heads=2, d_ff=64,
                    seq=16, batch=4, rank=4, group_size=16),
}


# --------------------------------------------------------------------------
# Parameter specs: the single source of truth for argument ordering.
# --------------------------------------------------------------------------

# The six LoRA-targeted linear maps of each block: (tag, in_dim, out_dim).
def linear_specs(cfg: Config):
    d, f = cfg.d_model, cfg.d_ff
    return [
        ("wq", d, d),
        ("wk", d, d),
        ("wv", d, d),
        ("wo", d, d),
        ("w_up", d, f),
        ("w_down", f, d),
    ]


def base_param_specs(cfg: Config):
    """Ordered (name, shape) for every base (frozen-at-finetune) parameter."""
    specs = [
        ("tok_emb", (cfg.vocab, cfg.d_model)),
        ("pos_emb", (cfg.seq, cfg.d_model)),
    ]
    for l in range(cfg.n_layers):
        specs += [
            (f"l{l}.ln1_g", (cfg.d_model,)),
            (f"l{l}.ln1_b", (cfg.d_model,)),
            (f"l{l}.ln2_g", (cfg.d_model,)),
            (f"l{l}.ln2_b", (cfg.d_model,)),
        ]
        for tag, din, dout in linear_specs(cfg):
            specs.append((f"l{l}.{tag}", (din, dout)))
    specs += [("ln_f_g", (cfg.d_model,)), ("ln_f_b", (cfg.d_model,))]
    return specs


def lora_param_specs(cfg: Config):
    """Ordered (name, shape) for the LoRA adapters (A: in×r, B: out×r)."""
    specs = []
    for l in range(cfg.n_layers):
        for tag, din, dout in linear_specs(cfg):
            specs.append((f"l{l}.{tag}.A", (din, cfg.rank)))
            specs.append((f"l{l}.{tag}.B", (dout, cfg.rank)))
    return specs


def quant_param_specs(cfg: Config):
    """Ordered (name, shape, dtype) for the quantized-weight inputs of the
    qeval path: per quantized linear, codes (i32) + scales + zeros."""
    gs = cfg.group_size
    specs = []
    for l in range(cfg.n_layers):
        for tag, din, dout in linear_specs(cfg):
            g = -(-din // gs)
            specs.append((f"l{l}.{tag}.codes", (din, dout), "i32"))
            specs.append((f"l{l}.{tag}.scales", (g, dout), "f32"))
            specs.append((f"l{l}.{tag}.zeros", (g, dout), "f32"))
    return specs


def nonquant_base_specs(cfg: Config):
    """Base params that stay in fp for the qeval path (embeddings + LNs —
    the paper quantizes 'all linear layers' of the blocks only)."""
    return [(n, s) for (n, s) in base_param_specs(cfg)
            if not any(t in n for t in ("wq", "wk", "wv", "wo", "w_up", "w_down"))]


def _unflatten(specs, args):
    assert len(specs) == len(args), (len(specs), len(args))
    return dict(zip([n for n, *_ in specs], args))


# --------------------------------------------------------------------------
# Forward pass
# --------------------------------------------------------------------------

def _layernorm(x, g, b, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _lora_linear(x, w, a, b):
    """x @ (W + A·Bᵀ) with the low-rank path kept factored."""
    return x @ w + (x @ a) @ b.T


def _attention(cfg: Config, x, base, lora, l, linear):
    bsz, t, d = x.shape
    h, dh = cfg.n_heads, cfg.d_head
    q = linear(x, f"l{l}.wq")
    k = linear(x, f"l{l}.wk")
    v = linear(x, f"l{l}.wv")
    q = q.reshape(bsz, t, h, dh).transpose(0, 2, 1, 3)
    k = k.reshape(bsz, t, h, dh).transpose(0, 2, 1, 3)
    v = v.reshape(bsz, t, h, dh).transpose(0, 2, 1, 3)
    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(dh).astype(jnp.float32)
    causal = jnp.tril(jnp.ones((t, t), jnp.bool_))
    att = jnp.where(causal, att, -1e9)
    att = jax.nn.softmax(att, axis=-1)
    y = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    y = y.transpose(0, 2, 1, 3).reshape(bsz, t, d)
    return linear(y, f"l{l}.wo")


def forward(cfg: Config, base, lora, tokens, collect_activations=False,
            quant=None):
    """Logits for `tokens` [B, T] int32.

    `base`/`lora` are name→array dicts. If `quant` is given (name→(codes,
    scales, zeros)), the six block linears run through the Pallas
    `qlora_matmul` kernel instead of dense matmul.
    If `collect_activations`, also returns the per-linear input activations
    (for Gram-matrix calibration).
    """
    acts = {}

    def linear(x, name):
        shp = x.shape
        x2 = x.reshape(-1, shp[-1])
        if collect_activations:
            acts[name] = x2
        a = lora[f"{name}.A"] if lora else None
        if quant is not None and name in quant:
            codes, scales, zeros = quant[name]
            if lora:
                y2 = qlora_matmul(x2, codes, scales, zeros, a, lora[f"{name}.B"],
                                  group_size=cfg.group_size)
            else:
                zero_a = jnp.zeros((shp[-1], 1), jnp.float32)
                zero_b = jnp.zeros((codes.shape[1], 1), jnp.float32)
                y2 = qlora_matmul(x2, codes, scales, zeros, zero_a, zero_b,
                                  group_size=cfg.group_size)
        elif lora:
            y2 = _lora_linear(x2, base[name], a, lora[f"{name}.B"])
        else:
            y2 = x2 @ base[name]
        return y2.reshape(*shp[:-1], y2.shape[-1])

    bsz, t = tokens.shape
    x = base["tok_emb"][tokens] + base["pos_emb"][None, :t, :]
    for l in range(cfg.n_layers):
        h = _layernorm(x, base[f"l{l}.ln1_g"], base[f"l{l}.ln1_b"])
        x = x + _attention(cfg, h, base, lora, l, linear)
        h = _layernorm(x, base[f"l{l}.ln2_g"], base[f"l{l}.ln2_b"])
        up = jax.nn.gelu(linear(h, f"l{l}.w_up"))
        x = x + linear(up, f"l{l}.w_down")
    x = _layernorm(x, base["ln_f_g"], base["ln_f_b"])
    logits = x @ base["tok_emb"].T  # tied head
    if collect_activations:
        return logits, acts
    return logits


def masked_loss(logits, tokens, mask):
    """(sum of CE over masked next-token positions, masked count).

    `mask[b, t] = 1` marks positions whose *prediction target* (token t)
    counts toward the loss; position 0 never has a target.
    """
    logp = jax.nn.log_softmax(logits[:, :-1, :], axis=-1)
    targets = tokens[:, 1:]
    m = mask[:, 1:]
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return (nll * m).sum(), m.sum()


# --------------------------------------------------------------------------
# AdamW (hand-rolled; optimizer state is part of the HLO interface)
# --------------------------------------------------------------------------

B1, B2, EPS = 0.9, 0.999, 1e-8


def adamw_update(params, grads, m, v, t, lr, wd):
    """One AdamW step over lists of arrays. `t` is the 1-based step (f32)."""
    new_p, new_m, new_v = [], [], []
    bc1 = 1.0 - B1**t
    bc2 = 1.0 - B2**t
    for p, g, mi, vi in zip(params, grads, m, v):
        mi = B1 * mi + (1 - B1) * g
        vi = B2 * vi + (1 - B2) * g * g
        mhat = mi / bc1
        vhat = vi / bc2
        p = p - lr * (mhat / (jnp.sqrt(vhat) + EPS) + wd * p)
        new_p.append(p)
        new_m.append(mi)
        new_v.append(vi)
    return new_p, new_m, new_v


# --------------------------------------------------------------------------
# Entry points (flat-argument functions + their manifests)
# --------------------------------------------------------------------------

def _spec_entry(name, shape, dtype="f32"):
    return {"name": name, "shape": list(shape), "dtype": dtype}


def build_entrypoints(cfg: Config):
    """Return {entry_name: (fn, [input specs], [output specs])}.

    Input/output specs are manifest dicts; `fn` takes the inputs as flat
    positional args in exactly the manifest order.
    """
    bspecs = base_param_specs(cfg)
    lspecs = lora_param_specs(cfg)
    qspecs = quant_param_specs(cfg)
    nqspecs = nonquant_base_specs(cfg)
    nb, nl, nq = len(bspecs), len(lspecs), len(qspecs)
    bt = (cfg.batch, cfg.seq)

    tok_in = _spec_entry("tokens", bt, "i32")
    mask_in = _spec_entry("mask", bt, "f32")
    scalar = lambda n: _spec_entry(n, (), "f32")

    entries = {}

    # ---- pretrain_step ----
    def pretrain_step(*args):
        base_vals = list(args[:nb])
        m = list(args[nb:2 * nb])
        v = list(args[2 * nb:3 * nb])
        tokens, mask, lr, wd, t = args[3 * nb:]

        def loss_fn(base_list):
            base = _unflatten(bspecs, base_list)
            logits = forward(cfg, base, None, tokens)
            s, c = masked_loss(logits, tokens, mask)
            return s / jnp.maximum(c, 1.0)

        loss, grads = jax.value_and_grad(loss_fn)(base_vals)
        new_p, new_m, new_v = adamw_update(base_vals, grads, m, v, t, lr, wd)
        return tuple(new_p) + tuple(new_m) + tuple(new_v) + (loss,)

    ins = ([_spec_entry(n, s) for n, s in bspecs]
           + [_spec_entry(f"m.{n}", s) for n, s in bspecs]
           + [_spec_entry(f"v.{n}", s) for n, s in bspecs]
           + [tok_in, mask_in, scalar("lr"), scalar("wd"), scalar("t")])
    outs = ([_spec_entry(n, s) for n, s in bspecs]
            + [_spec_entry(f"m.{n}", s) for n, s in bspecs]
            + [_spec_entry(f"v.{n}", s) for n, s in bspecs]
            + [scalar("loss")])
    entries["pretrain_step"] = (pretrain_step, ins, outs)

    # ---- lora_step ----
    def lora_step(*args):
        base = _unflatten(bspecs, args[:nb])
        lora_vals = list(args[nb:nb + nl])
        m = list(args[nb + nl:nb + 2 * nl])
        v = list(args[nb + 2 * nl:nb + 3 * nl])
        tokens, mask, lr, wd, t = args[nb + 3 * nl:]

        def loss_fn(lora_list):
            lora = _unflatten(lspecs, lora_list)
            logits = forward(cfg, base, lora, tokens)
            s, c = masked_loss(logits, tokens, mask)
            return s / jnp.maximum(c, 1.0)

        loss, grads = jax.value_and_grad(loss_fn)(lora_vals)
        new_p, new_m, new_v = adamw_update(lora_vals, grads, m, v, t, lr, wd)
        return tuple(new_p) + tuple(new_m) + tuple(new_v) + (loss,)

    ins = ([_spec_entry(n, s) for n, s in bspecs]
           + [_spec_entry(n, s) for n, s in lspecs]
           + [_spec_entry(f"m.{n}", s) for n, s in lspecs]
           + [_spec_entry(f"v.{n}", s) for n, s in lspecs]
           + [tok_in, mask_in, scalar("lr"), scalar("wd"), scalar("t")])
    outs = ([_spec_entry(n, s) for n, s in lspecs]
            + [_spec_entry(f"m.{n}", s) for n, s in lspecs]
            + [_spec_entry(f"v.{n}", s) for n, s in lspecs]
            + [scalar("loss")])
    entries["lora_step"] = (lora_step, ins, outs)

    # ---- eval_loss ----
    def eval_loss(*args):
        base = _unflatten(bspecs, args[:nb])
        lora = _unflatten(lspecs, args[nb:nb + nl])
        tokens, mask = args[nb + nl:]
        logits = forward(cfg, base, lora, tokens)
        s, c = masked_loss(logits, tokens, mask)
        return (s, c)

    ins = ([_spec_entry(n, s) for n, s in bspecs]
           + [_spec_entry(n, s) for n, s in lspecs] + [tok_in, mask_in])
    outs = [scalar("loss_sum"), scalar("count")]
    entries["eval_loss"] = (eval_loss, ins, outs)

    # ---- eval_logits ----
    def eval_logits(*args):
        base = _unflatten(bspecs, args[:nb])
        lora = _unflatten(lspecs, args[nb:nb + nl])
        tokens = args[nb + nl]
        return (forward(cfg, base, lora, tokens),)

    ins = ([_spec_entry(n, s) for n, s in bspecs]
           + [_spec_entry(n, s) for n, s in lspecs] + [tok_in])
    outs = [_spec_entry("logits", (cfg.batch, cfg.seq, cfg.vocab))]
    entries["eval_logits"] = (eval_logits, ins, outs)

    # ---- capture_grams ----
    def capture_grams(*args):
        base = _unflatten(bspecs, args[:nb])
        tokens, mask = args[nb:]
        logits, acts = forward(cfg, base, None, tokens, collect_activations=True)
        outs = []
        mask_flat = mask.reshape(-1, 1)
        for l in range(cfg.n_layers):
            for tag, _, _ in linear_specs(cfg):
                x = acts[f"l{l}.{tag}"] * mask_flat  # zero out pad rows
                outs.append(gram(x))  # L1 Pallas kernel
        # Keep the full forward (final LN, head) alive so XLA does not DCE
        # their parameters out of the HLO signature; also a useful
        # diagnostic that the captured model is numerically sane.
        checksum = (logits * mask[..., None]).mean()
        return tuple(outs) + (checksum,)

    ins = [_spec_entry(n, s) for n, s in bspecs] + [tok_in, mask_in]
    outs = []
    for l in range(cfg.n_layers):
        for tag, din, _ in linear_specs(cfg):
            outs.append(_spec_entry(f"l{l}.{tag}.H", (din, din)))
    outs.append(scalar("logit_checksum"))
    entries["capture_grams"] = (capture_grams, ins, outs)

    # ---- qeval_loss (quantized serving path through the Pallas kernel) ----
    def qeval_loss(*args):
        nnq = len(nqspecs)
        nonq = _unflatten(nqspecs, args[:nnq])
        qvals = args[nnq:nnq + nq]
        lora = _unflatten(lspecs, args[nnq + nq:nnq + nq + nl])
        tokens, mask = args[nnq + nq + nl:]
        quant = {}
        for i in range(0, nq, 3):
            name = qspecs[i][0].rsplit(".", 1)[0]  # strip ".codes"
            quant[name] = (qvals[i], qvals[i + 1], qvals[i + 2])
        # Base dict: embeddings + LNs are real, quantized linears are
        # placeholders (never read — the `quant` branch intercepts them).
        base = dict(nonq)
        for l in range(cfg.n_layers):
            for tag, din, dout in linear_specs(cfg):
                base[f"l{l}.{tag}"] = None
        logits = forward(cfg, base, lora, tokens, quant=quant)
        s, c = masked_loss(logits, tokens, mask)
        return (s, c)

    ins = ([_spec_entry(n, s) for n, s in nqspecs]
           + [_spec_entry(n, s, d) for n, s, d in qspecs]
           + [_spec_entry(n, s) for n, s in lspecs] + [tok_in, mask_in])
    outs = [scalar("loss_sum"), scalar("count")]
    entries["qeval_loss"] = (qeval_loss, ins, outs)

    return entries


def config_manifest(cfg: Config):
    d = asdict(cfg)
    d["d_head"] = cfg.d_head
    return d

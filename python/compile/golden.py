"""Golden-file generator: cross-language quantizer contract.

Writes `artifacts/golden.json` containing random weight matrices quantized
by the jnp reference (`kernels/ref.py`). `rust/tests/golden_quant.rs`
re-quantizes the same matrices with the Rust INT quantizer and asserts
code-exact agreement — pinning the L1 kernel's dequant semantics to the
L3 numerics.

Usage: python -m compile.golden --out ../artifacts/golden.json
"""

from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from compile.kernels.ref import dequant_ref, quantize_rtn_ref

jax.config.update("jax_platform_name", "cpu")


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts/golden.json")
    args = p.parse_args()

    rng = np.random.default_rng(20250710)
    cases = []
    for k, n, bits, gs in [
        (8, 4, 2, 4),
        (16, 8, 3, 8),
        (32, 8, 4, 16),
        (20, 6, 4, 8),   # partial last group
        (64, 16, 2, 64),
        (7, 3, 8, 4),
    ]:
        w = (rng.standard_normal((k, n)) * rng.uniform(0.05, 2.0)).astype(np.float32)
        codes, scales, zeros = quantize_rtn_ref(w, bits, gs)
        deq = dequant_ref(codes, scales, zeros, gs)
        cases.append({
            "k": k, "n": n, "bits": bits, "group_size": gs,
            "w": [float(x) for x in w.flatten()],
            "codes": [int(x) for x in np.asarray(codes).flatten()],
            "scales": [float(x) for x in np.asarray(scales).flatten()],
            "zeros": [float(x) for x in np.asarray(zeros).flatten()],
            "deq": [float(x) for x in np.asarray(deq).flatten()],
        })
    with open(args.out, "w") as f:
        json.dump({"cases": cases}, f)
    print(f"wrote {len(cases)} golden cases to {args.out}")


if __name__ == "__main__":
    main()

"""L1 kernel correctness: Pallas vs pure-jnp oracle.

The hypothesis sweeps are the heart of this suite: shapes, ranks, group
sizes and bit-widths are all drawn adversarially and the kernel must match
`ref.py` to f32 tolerance on every draw.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.qlora_matmul import gram, qlora_matmul
from compile.kernels.ref import (
    dequant_ref,
    gram_ref,
    qlora_matmul_ref,
    quantize_rtn_ref,
)

jax.config.update("jax_platform_name", "cpu")


def make_case(rng, m, k, n, r, bits, gs):
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, n)) * 0.5, jnp.float32)
    codes, scales, zeros = quantize_rtn_ref(w, bits, gs)
    a = jnp.asarray(rng.standard_normal((k, r)) * 0.1, jnp.float32)
    b = jnp.asarray(rng.standard_normal((n, r)) * 0.1, jnp.float32)
    return x, codes, scales, zeros, a, b


class TestQloraMatmul:
    def test_basic_exact_match(self):
        rng = np.random.default_rng(0)
        x, codes, scales, zeros, a, b = make_case(rng, 16, 32, 24, 4, 4, 8)
        got = qlora_matmul(x, codes, scales, zeros, a, b, group_size=8)
        want = qlora_matmul_ref(x, codes, scales, zeros, a, b, 8)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_zero_lora_is_pure_dequant_matmul(self):
        rng = np.random.default_rng(1)
        x, codes, scales, zeros, a, b = make_case(rng, 8, 16, 8, 2, 2, 16)
        a = jnp.zeros_like(a)
        got = qlora_matmul(x, codes, scales, zeros, a, b, group_size=16)
        want = x @ dequant_ref(codes, scales, zeros, 16)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_tiling_boundaries(self):
        # Shapes that do NOT divide the block sizes exercise the padding path.
        rng = np.random.default_rng(2)
        x, codes, scales, zeros, a, b = make_case(rng, 70, 48, 130, 8, 4, 16)
        got = qlora_matmul(x, codes, scales, zeros, a, b,
                           group_size=16, block_m=64, block_n=128)
        want = qlora_matmul_ref(x, codes, scales, zeros, a, b, 16)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_partial_last_group(self):
        rng = np.random.default_rng(3)
        # K=20 with gs=8 → 3 groups, last partial.
        x, codes, scales, zeros, a, b = make_case(rng, 4, 20, 6, 2, 3, 8)
        got = qlora_matmul(x, codes, scales, zeros, a, b, group_size=8)
        want = qlora_matmul_ref(x, codes, scales, zeros, a, b, 8)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    @settings(max_examples=40, deadline=None)
    @given(
        m=st.integers(1, 40),
        k=st.integers(1, 48),
        n=st.integers(1, 48),
        r=st.integers(1, 8),
        bits=st.sampled_from([2, 3, 4, 8]),
        gs_pow=st.integers(1, 5),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_sweep(self, m, k, n, r, bits, gs_pow, seed):
        gs = 2**gs_pow
        rng = np.random.default_rng(seed)
        x, codes, scales, zeros, a, b = make_case(rng, m, k, n, r, bits, gs)
        got = qlora_matmul(x, codes, scales, zeros, a, b,
                           group_size=gs, block_m=16, block_n=32)
        want = qlora_matmul_ref(x, codes, scales, zeros, a, b, gs)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    @settings(max_examples=15, deadline=None)
    @given(
        bm=st.sampled_from([8, 16, 64]),
        bn=st.sampled_from([16, 32, 128]),
        seed=st.integers(0, 1000),
    )
    def test_block_shape_invariance(self, bm, bn, seed):
        # The result must not depend on the tiling.
        rng = np.random.default_rng(seed)
        x, codes, scales, zeros, a, b = make_case(rng, 33, 24, 40, 4, 4, 8)
        got = qlora_matmul(x, codes, scales, zeros, a, b,
                           group_size=8, block_m=bm, block_n=bn)
        want = qlora_matmul_ref(x, codes, scales, zeros, a, b, 8)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


class TestGram:
    def test_basic(self):
        rng = np.random.default_rng(4)
        x = jnp.asarray(rng.standard_normal((100, 12)), jnp.float32)
        np.testing.assert_allclose(gram(x), gram_ref(x), rtol=1e-4, atol=1e-4)

    @settings(max_examples=20, deadline=None)
    @given(
        s=st.integers(1, 300),
        f=st.integers(1, 32),
        bs=st.sampled_from([16, 64, 128]),
        seed=st.integers(0, 1000),
    )
    def test_hypothesis_sweep(self, s, f, bs, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal((s, f)), jnp.float32)
        got = gram(x, block_s=bs)
        np.testing.assert_allclose(got, gram_ref(x), rtol=1e-3, atol=1e-3)

    def test_symmetry_psd(self):
        rng = np.random.default_rng(5)
        x = jnp.asarray(rng.standard_normal((64, 16)), jnp.float32)
        h = np.asarray(gram(x))
        np.testing.assert_allclose(h, h.T, atol=1e-5)
        evals = np.linalg.eigvalsh(h)
        assert evals.min() > -1e-3


class TestQuantizerRef:
    """The jnp quantizer itself (also the source of Rust golden files)."""

    @settings(max_examples=30, deadline=None)
    @given(
        k=st.integers(1, 64),
        n=st.integers(1, 16),
        bits=st.sampled_from([2, 3, 4, 8]),
        gs=st.sampled_from([4, 16, 64]),
        seed=st.integers(0, 1000),
    )
    def test_roundtrip_error_bound(self, k, n, bits, gs, seed):
        rng = np.random.default_rng(seed)
        w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
        codes, scales, zeros = quantize_rtn_ref(w, bits, gs)
        deq = dequant_ref(codes, scales, zeros, gs)
        row_group = np.arange(k) // gs
        step = np.asarray(scales)[row_group]
        # |w - deq| ≤ scale (half-step rounding + half-step zero rounding).
        assert np.all(np.abs(np.asarray(w - deq)) <= step + 1e-5)

    def test_codes_in_range(self):
        rng = np.random.default_rng(6)
        w = jnp.asarray(rng.standard_normal((32, 8)) * 10, jnp.float32)
        for bits in (2, 3, 4):
            codes, _, _ = quantize_rtn_ref(w, bits, 8)
            assert int(codes.min()) >= 0
            assert int(codes.max()) <= 2**bits - 1


if __name__ == "__main__":
    pytest.main([__file__, "-q"])

"""AOT lowering contract tests: manifest specs must exactly describe the
lowered HLO (parameter counts, shapes, dtypes) — this is the interface the
Rust runtime trusts blindly."""

import re

import jax
import pytest

from compile.aot import lower_entry, to_hlo_text
from compile.model import PRESETS, build_entrypoints

jax.config.update("jax_platform_name", "cpu")

CFG = PRESETS["micro"]


@pytest.fixture(scope="module")
def entries():
    return build_entrypoints(CFG)


def test_manifest_specs_are_well_formed(entries):
    for name, (fn, ins, outs) in entries.items():
        assert callable(fn)
        for spec in ins + outs:
            assert set(spec) == {"name", "shape", "dtype"}, (name, spec)
            assert spec["dtype"] in ("f32", "i32")
            assert all(isinstance(d, int) and d >= 0 for d in spec["shape"])
        # Names unique within a side.
        in_names = [s["name"] for s in ins]
        assert len(in_names) == len(set(in_names)), name


def test_hlo_parameter_count_matches_manifest(entries):
    # Lower the two smallest entries and count HLO parameters.
    for name in ("eval_loss", "capture_grams"):
        fn, ins, outs = entries[name]
        text = lower_entry(fn, ins)
        assert "ENTRY" in text
        params = re.findall(r"parameter\((\d+)\)", text)
        assert len(set(params)) == len(ins), (
            f"{name}: {len(set(params))} HLO params vs {len(ins)} manifest inputs"
        )
        # return_tuple=True → root is a tuple of len(outs).
        assert "tuple(" in text.lower() or len(outs) == 1


def test_lora_step_output_matches_input_lora_shapes(entries):
    fn, ins, outs = entries["lora_step"]
    lora_in = [s for s in ins if s["name"].endswith((".A", ".B"))]
    lora_out = [s for s in outs if s["name"].endswith((".A", ".B"))]
    assert [s["shape"] for s in lora_in] == [s["shape"] for s in lora_out]


def test_presets_are_consistent():
    for name, cfg in PRESETS.items():
        assert cfg.name == name or cfg.name.startswith(name)
        assert cfg.d_model % cfg.n_heads == 0, name
        assert cfg.vocab == 260
        assert cfg.rank <= min(cfg.d_model, cfg.d_ff), name


def test_to_hlo_text_smoke():
    import jax.numpy as jnp

    def f(x):
        return (x * 2.0,)

    lowered = jax.jit(f).lower(jax.ShapeDtypeStruct((4,), jnp.float32))
    text = to_hlo_text(lowered)
    assert "HloModule" in text


if __name__ == "__main__":
    pytest.main([__file__, "-q"])

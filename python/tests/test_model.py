"""L2 model tests: shapes, loss semantics, LoRA algebra, training step
behaviour, and the quantized path vs the dense path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.ref import quantize_rtn_ref
from compile.model import (
    PRESETS,
    base_param_specs,
    build_entrypoints,
    forward,
    lora_param_specs,
    masked_loss,
    nonquant_base_specs,
    quant_param_specs,
)

jax.config.update("jax_platform_name", "cpu")

CFG = PRESETS["micro"]


def init_base(rng):
    base = {}
    for n, s in base_param_specs(CFG):
        if n.endswith("_g"):
            base[n] = jnp.ones(s, jnp.float32)
        elif n.endswith("_b"):
            base[n] = jnp.zeros(s, jnp.float32)
        else:
            base[n] = jnp.asarray(rng.standard_normal(s) * 0.05, jnp.float32)
    return base


def init_lora(rng, zero_b=True):
    lora = {}
    for n, s in lora_param_specs(CFG):
        if n.endswith(".B") and zero_b:
            lora[n] = jnp.zeros(s, jnp.float32)
        else:
            lora[n] = jnp.asarray(rng.standard_normal(s) * 0.05, jnp.float32)
    return lora


def batch(rng):
    tokens = jnp.asarray(
        rng.integers(4, CFG.vocab, size=(CFG.batch, CFG.seq)), jnp.int32)
    mask = jnp.ones((CFG.batch, CFG.seq), jnp.float32)
    return tokens, mask


class TestForward:
    def test_logits_shape_finite(self):
        rng = np.random.default_rng(0)
        base, lora = init_base(rng), init_lora(rng)
        tokens, _ = batch(rng)
        logits = forward(CFG, base, lora, tokens)
        assert logits.shape == (CFG.batch, CFG.seq, CFG.vocab)
        assert bool(jnp.isfinite(logits).all())

    def test_zero_b_lora_matches_base_model(self):
        rng = np.random.default_rng(1)
        base = init_base(rng)
        lora = init_lora(rng, zero_b=True)
        tokens, _ = batch(rng)
        l1 = forward(CFG, base, lora, tokens)
        l2 = forward(CFG, base, None, tokens)
        np.testing.assert_allclose(l1, l2, atol=1e-5)

    def test_lora_equals_merged_weights(self):
        # forward(base, lora) == forward(base + A·Bᵀ merged, no lora)
        rng = np.random.default_rng(2)
        base = init_base(rng)
        lora = init_lora(rng, zero_b=False)
        tokens, _ = batch(rng)
        merged = dict(base)
        for l in range(CFG.n_layers):
            for tag in ("wq", "wk", "wv", "wo", "w_up", "w_down"):
                n = f"l{l}.{tag}"
                merged[n] = base[n] + lora[f"{n}.A"] @ lora[f"{n}.B"].T
        l1 = forward(CFG, base, lora, tokens)
        l2 = forward(CFG, merged, None, tokens)
        np.testing.assert_allclose(l1, l2, rtol=2e-4, atol=2e-4)

    def test_causality(self):
        # Changing token t must not change logits at positions < t.
        rng = np.random.default_rng(3)
        base = init_base(rng)
        tokens, _ = batch(rng)
        l1 = forward(CFG, base, None, tokens)
        perturbed = tokens.at[:, -1].set((tokens[:, -1] + 1) % CFG.vocab)
        l2 = forward(CFG, base, None, perturbed)
        np.testing.assert_allclose(l1[:, :-1, :], l2[:, :-1, :], atol=1e-5)
        assert float(jnp.abs(l1[:, -1] - l2[:, -1]).max()) > 1e-4

    def test_masked_loss_semantics(self):
        rng = np.random.default_rng(4)
        base = init_base(rng)
        tokens, mask = batch(rng)
        logits = forward(CFG, base, None, tokens)
        s_full, c_full = masked_loss(logits, tokens, mask)
        assert int(c_full) == CFG.batch * (CFG.seq - 1)
        # Half mask → half count, and loss sum must drop.
        half = mask.at[:, : CFG.seq // 2].set(0.0)
        s_half, c_half = masked_loss(logits, tokens, half)
        assert int(c_half) < int(c_full)
        assert float(s_half) < float(s_full)

    def test_random_model_loss_near_uniform(self):
        rng = np.random.default_rng(5)
        base = init_base(rng)
        tokens, mask = batch(rng)
        logits = forward(CFG, base, None, tokens)
        s, c = masked_loss(logits, tokens, mask)
        # Untrained model ≈ uniform: CE ≈ ln(vocab).
        assert abs(float(s / c) - np.log(CFG.vocab)) < 1.0


class TestEntrypoints:
    @pytest.fixture(scope="class")
    def entries(self):
        return build_entrypoints(CFG)

    def _inputs_for(self, specs, rng):
        vals = []
        for s in specs:
            shape = tuple(s["shape"])
            if s["dtype"] == "i32":
                if s["name"] == "tokens":
                    vals.append(jnp.asarray(
                        rng.integers(4, CFG.vocab, size=shape), jnp.int32))
                else:
                    vals.append(jnp.zeros(shape, jnp.int32))
            elif s["name"] == "mask":
                vals.append(jnp.ones(shape, jnp.float32))
            elif s["name"] == "lr":
                vals.append(jnp.asarray(1e-3, jnp.float32))
            elif s["name"] == "wd":
                vals.append(jnp.asarray(0.0, jnp.float32))
            elif s["name"] == "t":
                vals.append(jnp.asarray(1.0, jnp.float32))
            elif s["name"].startswith(("m.", "v.")):
                vals.append(jnp.zeros(shape, jnp.float32))
            elif s["name"].endswith("_g"):
                vals.append(jnp.ones(shape, jnp.float32))
            elif s["name"].endswith(".B"):
                vals.append(jnp.zeros(shape, jnp.float32))
            else:
                vals.append(jnp.asarray(
                    rng.standard_normal(shape) * 0.05, jnp.float32))
        return vals

    def test_pretrain_step_decreases_loss(self, entries):
        fn, ins, outs = entries["pretrain_step"]
        rng = np.random.default_rng(6)
        vals = self._inputs_for(ins, rng)
        nb = len(base_param_specs(CFG))
        jfn = jax.jit(fn)
        losses = []
        for step in range(12):
            res = jfn(*vals)
            losses.append(float(res[-1]))
            # Feed params/m/v back; bump t.
            vals[: 3 * nb] = list(res[: 3 * nb])
            vals[-1] = jnp.asarray(float(step + 2), jnp.float32)
        assert losses[-1] < losses[0] - 0.1, losses

    def test_lora_step_trains_only_lora(self, entries):
        fn, ins, outs = entries["lora_step"]
        rng = np.random.default_rng(7)
        vals = self._inputs_for(ins, rng)
        # Break the zero-B init so gradients flow through both factors.
        nb = len(base_param_specs(CFG))
        nl = len(lora_param_specs(CFG))
        for i in range(nb, nb + nl):
            vals[i] = jnp.asarray(
                rng.standard_normal(vals[i].shape) * 0.05, jnp.float32)
        jfn = jax.jit(fn)
        losses = []
        for step in range(12):
            res = jfn(*vals)
            losses.append(float(res[-1]))
            vals[nb: nb + 3 * nl] = list(res[: 3 * nl])
            vals[-1] = jnp.asarray(float(step + 2), jnp.float32)
        assert losses[-1] < losses[0], losses

    def test_eval_matches_forward(self, entries):
        fn, ins, outs = entries["eval_loss"]
        rng = np.random.default_rng(8)
        vals = self._inputs_for(ins, rng)
        s, c = fn(*vals)
        assert int(c) == CFG.batch * (CFG.seq - 1)
        assert 1.0 < float(s) / float(c) < 10.0

    def test_capture_grams_psd_and_shapes(self, entries):
        fn, ins, outs = entries["capture_grams"]
        rng = np.random.default_rng(9)
        vals = self._inputs_for(ins, rng)
        *grams, checksum = fn(*vals)
        assert len(grams) == 6 * CFG.n_layers
        assert np.isfinite(float(checksum))
        for g, spec in zip(grams, outs):
            assert g.shape == tuple(spec["shape"])
            gn = np.asarray(g)
            np.testing.assert_allclose(gn, gn.T, atol=1e-3)
            assert np.linalg.eigvalsh(gn).min() > -1e-2

    def test_qeval_matches_dense_eval_on_grid_weights(self, entries):
        """The quantized serving path == dense path when base weights are
        exactly the dequantized values — the L1/L2 consistency contract the
        Rust runtime relies on."""
        rng = np.random.default_rng(10)
        eval_fn, eval_ins, _ = entries["eval_loss"]
        qeval_fn, qeval_ins, _ = entries["qeval_loss"]

        # Build a base model, quantize its linears, dequantize back.
        base = init_base(rng)
        quant = {}
        for l in range(CFG.n_layers):
            for tag in ("wq", "wk", "wv", "wo", "w_up", "w_down"):
                n = f"l{l}.{tag}"
                codes, scales, zeros = quantize_rtn_ref(
                    base[n], 4, CFG.group_size)
                quant[n] = (codes, scales, zeros)
                # dense path sees the dequantized values
                from compile.kernels.ref import dequant_ref
                base[n] = dequant_ref(codes, scales, zeros, CFG.group_size)
        lora = init_lora(rng, zero_b=False)
        tokens, mask = batch(rng)

        ev = [base[s["name"]] for s in eval_ins[: len(base_param_specs(CFG))]]
        ev += [lora[s["name"]] for s in eval_ins[len(ev): len(ev) + len(lora_param_specs(CFG))]]
        ev += [tokens, mask]
        s1, c1 = eval_fn(*ev)

        qv = [base[n] for n, _ in nonquant_base_specs(CFG)]
        for n, _, _ in quant_param_specs(CFG):
            layer, kind = n.rsplit(".", 1)
            qv.append(quant[layer][("codes", "scales", "zeros").index(kind)])
        qv += [lora[n] for n, _ in lora_param_specs(CFG)]
        qv += [tokens, mask]
        s2, c2 = qeval_fn(*qv)

        assert int(c1) == int(c2)
        np.testing.assert_allclose(float(s1), float(s2), rtol=2e-3)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
